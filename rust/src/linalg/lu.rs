//! Small dense LU with partial pivoting.
//!
//! Used on `m×m` Hessians (m ≤ ~10 hyperparameters): determinant for the
//! Laplace evidence (eq. 2.13), inverse for hyperparameter error bars
//! (§2(a): "the inverse of the Hessian is the covariance matrix of the
//! maximum hyperlikelihood estimator").

use super::Matrix;

/// LU factorisation `P A = L U` with partial pivoting.
pub struct Lu {
    lu: Matrix,
    piv: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factor; fails on exact singularity.
    pub fn factor(a: &Matrix) -> crate::Result<Self> {
        anyhow::ensure!(a.rows() == a.cols(), "LU needs a square matrix");
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot search
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            anyhow::ensure!(best > 0.0, "singular matrix at column {k}");
            if p != k {
                let (a, b) = lu.rows_mut2(k, p);
                a.swap_with_slice(b);
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                for j in (k + 1)..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= f * v;
                }
            }
        }
        Ok(Self { lu, piv, sign })
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let n = self.lu.rows();
        let mut d = self.sign;
        for i in 0..n {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// `ln |det A|` and its sign — used for `ln det H` in eq. (2.13)
    /// without overflow for large Hessian entries.
    pub fn logdet_abs(&self) -> (f64, f64) {
        let n = self.lu.rows();
        let mut logdet = 0.0;
        let mut sign = self.sign;
        for i in 0..n {
            let d = self.lu[(i, i)];
            logdet += d.abs().ln();
            if d < 0.0 {
                sign = -sign;
            }
        }
        (logdet, sign)
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward: L y = Pb (unit diagonal)
        for i in 0..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s;
        }
        // backward: U x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        x
    }

    /// Explicit inverse (only for small m).
    pub fn inverse(&self) -> Matrix {
        let n = self.lu.rows();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = x[i];
            }
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn det_2x2() {
        let a = Matrix::from_rows(&[&[3.0, 1.0], &[4.0, 2.0]]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() - 2.0).abs() < 1e-13);
        let (ld, s) = lu.logdet_abs();
        assert!((ld - 2f64.ln()).abs() < 1e-13);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn det_sign_negative() {
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-14);
        let (_, s) = lu.logdet_abs();
        assert_eq!(s, -1.0);
    }

    #[test]
    fn solve_and_inverse_random() {
        let mut rng = Xoshiro256::seed_from_u64(43);
        for &n in &[1usize, 2, 5, 8] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = rng.normal();
                }
                a[(i, i)] += 3.0; // keep well-conditioned
            }
            let lu = Lu::factor(&a).unwrap();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = lu.solve(&b);
            let r = a.matvec(&x);
            for i in 0..n {
                assert!((r[i] - b[i]).abs() < 1e-10);
            }
            let inv = lu.inverse();
            let prod = a.matmul(&inv);
            assert!(prod.max_abs_diff(&Matrix::eye(n)) < 1e-10);
        }
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }
}
