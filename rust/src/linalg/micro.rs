//! Cache-blocked, register-tiled f64 micro-kernels — the innermost layer
//! of the crate's three-level performance architecture (see `lib.rs`):
//!
//! 1. **threads** — [`crate::runtime::ExecutionContext`] splits output
//!    rows across scoped threads;
//! 2. **cache blocks** — each thread's GEMM walks `KC×NC` panels of `B`
//!    and `MC×KC` panels of `A`, packed into contiguous scratch so the
//!    innermost loops stream L1-resident data;
//! 3. **register tiles** — an `MR×NR` block of `C` is held in FMA
//!    accumulators (`f64::mul_add`) for the whole `KC` depth.
//!
//! Everything here operates on raw row-major slices with an explicit row
//! stride, so the same kernels serve full matrices, sub-blocks of a
//! matrix being factorised in place, and packed panels.
//!
//! ## The canonical accumulation-order contract
//!
//! Every `C` entry owns a private accumulator: its value is
//! `C₀ + α·Σ_chunk(Σ_k fma…)` where the `k` chunk grid depends only on
//! the *call's* `k` origin and the global `KC` constant — never on which
//! thread computed the entry, how the output rows were chunked, or how
//! many other rows the call processed. Results are therefore
//! **bit-identical for any thread count and any row partition** (asserted
//! in `rust/tests/micro_kernels.rs` and `rust/tests/parallel_equivalence.rs`).
//! They *do* differ from a naive triple loop by rounding (different
//! summation order, fused multiply-adds); the golden-value suite's 1e-8
//! tolerance absorbs this, and reconstruction/residual tests pass
//! unchanged.
//!
//! ## Triangular variants
//!
//! [`gemm_nt`] with a [`Clip`] is the SYRK building block: the update is
//! computed tile-by-tile but only the requested trapezoid of `C` is
//! written, so `C −= P·Pᵀ` restricted to the lower triangle (the blocked
//! Cholesky's trailing update) and `W = U·Uᵀ`-style upper-triangle
//! products reuse the one macro-kernel. [`solve_lower_rows`] /
//! [`solve_lower_transpose_rows`] are blocked multi-RHS TRSMs: column
//! blocks of width [`TB`] are eliminated with a GEMM against the
//! already-solved columns (mirrored into a scratch buffer so the in-place
//! update needs no aliased borrows), then a small scalar triangle solve
//! finishes the block.

use std::cell::RefCell;

/// Register-tile rows: each micro-kernel invocation accumulates `MR`
/// rows of `C`.
pub const MR: usize = 4;
/// Register-tile columns (`MR·NR` f64 accumulators ≈ 8 AVX registers).
pub const NR: usize = 8;
/// Depth of one packed panel pass; per-entry k-sums are chunked on this
/// grid (part of the canonical accumulation-order contract).
pub const KC: usize = 256;
/// Rows of `A` packed per macro-tile (`MC·KC` doubles ≈ 128 KiB ≈ L2).
pub const MC: usize = 64;
/// Columns of `B` packed per macro-tile.
pub const NC: usize = 512;
/// Column-block width of the blocked TRSMs.
pub const TB: usize = 32;

/// Reusable per-thread scratch for the packed panels and the TRSM
/// mirror buffer. A `gemm` call at full blocking packs
/// `MC·KC + NC·KC` doubles (≈1.2 MB zeroed) — allocated fresh on every
/// call this cost a few % of an n = 2000 factorisation (~500 calls).
/// Buffers are **taken out** of the slot for the duration of a call and
/// put back after (so the TRSMs' mirror and the GEMMs they invoke never
/// alias a shared borrow); they only ever grow, and their stale contents
/// are never read — packing overwrites exactly the region each kernel
/// consumes, and the TRSM mirror is written block-by-block before the
/// eliminations that read it.
struct PackArena {
    a: Vec<f64>,
    b: Vec<f64>,
    mirror: Vec<f64>,
}

thread_local! {
    static PACK_ARENA: RefCell<PackArena> =
        const { RefCell::new(PackArena { a: Vec::new(), b: Vec::new(), mirror: Vec::new() }) };
}

fn slot_a(ar: &mut PackArena) -> &mut Vec<f64> {
    &mut ar.a
}
fn slot_b(ar: &mut PackArena) -> &mut Vec<f64> {
    &mut ar.b
}
fn slot_mirror(ar: &mut PackArena) -> &mut Vec<f64> {
    &mut ar.mirror
}

/// Take a buffer of at least `len` elements out of the arena slot
/// selected by `pick` (growing it if needed — the only case that
/// allocates). The caller must hand it back with [`arena_put`].
fn arena_take(
    pick: fn(&mut PackArena) -> &mut Vec<f64>,
    len: usize,
) -> Vec<f64> {
    let mut buf = PACK_ARENA.with(|ar| std::mem::take(pick(&mut *ar.borrow_mut())));
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    buf
}

fn arena_put(pick: fn(&mut PackArena) -> &mut Vec<f64>, buf: Vec<f64>) {
    PACK_ARENA.with(|ar| {
        let mut ar = ar.borrow_mut();
        let slot = pick(&mut *ar);
        // keep the larger of the two (a reentrant call may have regrown
        // the slot); dropping the smaller is the cold path
        if slot.len() < buf.len() {
            *slot = buf;
        }
    });
}

/// Which trapezoid of the `C` region a clipped GEMM may write.
///
/// Indices are local to the `C` region passed in; the caller folds any
/// global row/column offsets into `shift`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Clip {
    /// Write every entry.
    None,
    /// Write `c[i][j]` only when `j <= i + shift`.
    Lower(isize),
    /// Write `c[i][j]` only when `j >= i + shift`.
    Upper(isize),
}

impl Clip {
    /// Does the `rows×cols` block at local `(i0, j0)` contain any
    /// writable entry?
    #[inline]
    fn live(self, i0: isize, rows: usize, j0: isize, cols: usize) -> bool {
        match self {
            Clip::None => true,
            Clip::Lower(s) => j0 <= i0 + rows as isize - 1 + s,
            Clip::Upper(s) => j0 + cols as isize - 1 >= i0 + s,
        }
    }

    /// Writable local-column range `[lo, hi)` for the row at local index
    /// `i`, inside a tile whose first column has local index `j0` and
    /// which spans `nr` columns.
    #[inline]
    fn col_range(self, i: isize, j0: isize, nr: usize) -> (usize, usize) {
        match self {
            Clip::None => (0, nr),
            Clip::Lower(s) => {
                let max_j = i + s - j0; // inclusive
                if max_j < 0 {
                    (0, 0)
                } else {
                    (0, nr.min(max_j as usize + 1))
                }
            }
            Clip::Upper(s) => {
                let min_j = i + s - j0; // inclusive
                if min_j <= 0 {
                    (0, nr)
                } else {
                    (nr.min(min_j as usize), nr)
                }
            }
        }
    }
}

#[inline]
fn round_up(x: usize, to: usize) -> usize {
    (x + to - 1) / to * to
}

/// Pack `mc` rows of `A` (rows `m0..m0+mc`, columns `k0..k0+kc`, row
/// stride `ars`) into `MR`-row micro-panels:
/// `out[ip·MR·kc + kk·MR + ii] = A[m0+ip·MR+ii][k0+kk]`, zero-padded so
/// the kernel never reads past the true row count.
fn pack_a(a: &[f64], ars: usize, m0: usize, mc: usize, k0: usize, kc: usize, out: &mut [f64]) {
    let panels = (mc + MR - 1) / MR;
    for ip in 0..panels {
        let dst = &mut out[ip * MR * kc..(ip + 1) * MR * kc];
        let r_base = m0 + ip * MR;
        let rows = MR.min(mc - ip * MR);
        for ii in 0..rows {
            let start = (r_base + ii) * ars + k0;
            let src = &a[start..start + kc];
            for (kk, &v) in src.iter().enumerate() {
                dst[kk * MR + ii] = v;
            }
        }
        for ii in rows..MR {
            for kk in 0..kc {
                dst[kk * MR + ii] = 0.0;
            }
        }
    }
}

/// Pack a `kc×nc` block of a **normal** `B` (row index = k):
/// `out[jp·NR·kc + kk·NR + jj] = B[k0+kk][n0+jp·NR+jj]`, zero-padded.
fn pack_b_n(b: &[f64], brs: usize, k0: usize, kc: usize, n0: usize, nc: usize, out: &mut [f64]) {
    let panels = (nc + NR - 1) / NR;
    for jp in 0..panels {
        let dst = &mut out[jp * NR * kc..(jp + 1) * NR * kc];
        let c_base = n0 + jp * NR;
        let cols = NR.min(nc - jp * NR);
        for kk in 0..kc {
            let start = (k0 + kk) * brs + c_base;
            let src = &b[start..start + cols];
            let d = &mut dst[kk * NR..kk * NR + NR];
            d[..cols].copy_from_slice(src);
            for slot in d[cols..].iter_mut() {
                *slot = 0.0;
            }
        }
    }
}

/// Pack a block of a **transposed** `B` operand (`B` stored `n×k`
/// row-major, used as `Bᵀ`): `out[… kk·NR + jj] = B[n0+jp·NR+jj][k0+kk]`.
fn pack_b_t(b: &[f64], brs: usize, k0: usize, kc: usize, n0: usize, nc: usize, out: &mut [f64]) {
    let panels = (nc + NR - 1) / NR;
    for jp in 0..panels {
        let dst = &mut out[jp * NR * kc..(jp + 1) * NR * kc];
        let c_base = n0 + jp * NR;
        let cols = NR.min(nc - jp * NR);
        for jj in 0..cols {
            let start = (c_base + jj) * brs + k0;
            let src = &b[start..start + kc];
            for (kk, &v) in src.iter().enumerate() {
                dst[kk * NR + jj] = v;
            }
        }
        for jj in cols..NR {
            for kk in 0..kc {
                dst[kk * NR + jj] = 0.0;
            }
        }
    }
}

/// The register kernel: accumulate `ap·bpᵀ` (both packed, depth `kc`)
/// into an `MR×NR` tile of FMA accumulators, then apply the writable
/// `mr×nr` part to `C` as `c += alpha·acc`.
///
/// `gi`/`gj` are the tile's local coordinates inside the `C` region
/// (for the clip test only).
#[inline]
fn micro_kernel(
    ap: &[f64],
    bp: &[f64],
    c: &mut [f64],
    cs: usize,
    mr: usize,
    nr: usize,
    alpha: f64,
    gi: isize,
    gj: isize,
    clip: Clip,
) {
    let mut acc = [[0.0f64; NR]; MR];
    // `chunks_exact` keeps the hot loop free of bounds checks and lets
    // LLVM lift the MR×NR body into registers.
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for ii in 0..MR {
            let a = av[ii];
            for jj in 0..NR {
                acc[ii][jj] = a.mul_add(bv[jj], acc[ii][jj]);
            }
        }
    }
    for ii in 0..mr {
        let (lo, hi) = clip.col_range(gi + ii as isize, gj, nr);
        if lo >= hi {
            continue;
        }
        let row = &mut c[ii * cs + lo..ii * cs + hi];
        let arow = &acc[ii];
        for (jj, cv) in row.iter_mut().enumerate() {
            *cv += alpha * arow[lo + jj];
        }
    }
}

/// Sweep the packed panels over one `mc×nc` macro-tile of `C` at local
/// origin `(i0, j0)`. The `jr` loop is outer so each `B` micro-panel
/// stays hot while the `A` panels stream past it.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    c: &mut [f64],
    cs: usize,
    i0: usize,
    mc: usize,
    j0: usize,
    nc: usize,
    kc: usize,
    apack: &[f64],
    bpack: &[f64],
    alpha: f64,
    clip: Clip,
) {
    let mut jr = 0;
    while jr < nc {
        let nr = NR.min(nc - jr);
        let bp = &bpack[(jr / NR) * NR * kc..(jr / NR + 1) * NR * kc];
        let tj = (j0 + jr) as isize;
        let mut ir = 0;
        while ir < mc {
            let mr = MR.min(mc - ir);
            let ti = (i0 + ir) as isize;
            if clip.live(ti, mr, tj, nr) {
                let ap = &apack[(ir / MR) * MR * kc..(ir / MR + 1) * MR * kc];
                let coff = (i0 + ir) * cs + j0 + jr;
                micro_kernel(ap, bp, &mut c[coff..], cs, mr, nr, alpha, ti, tj, clip);
            }
            ir += MR;
        }
        jr += NR;
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    c: &mut [f64],
    cs: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    b: &[f64],
    brs: usize,
    alpha: f64,
    clip: Clip,
    b_transposed: bool,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    assert!(c.len() >= (m - 1) * cs + n, "C region too short");
    assert!(a.len() >= (m - 1) * ars + k, "A region too short");
    if b_transposed {
        assert!(b.len() >= (n - 1) * brs + k, "Bᵀ region too short");
    } else {
        assert!(b.len() >= (k - 1) * brs + n, "B region too short");
    }
    let kc_max = KC.min(k);
    let a_len = MC.min(round_up(m, MR)) * kc_max;
    let b_len = NC.min(round_up(n, NR)) * kc_max;
    // per-thread reusable pack scratch: no allocation once warm
    let mut abuf = arena_take(slot_a, a_len);
    let mut bbuf = arena_take(slot_b, b_len);
    let apack = &mut abuf[..a_len];
    let bpack = &mut bbuf[..b_len];
    let mut j0 = 0;
    while j0 < n {
        let nc = NC.min(n - j0);
        let mut k0 = 0;
        while k0 < k {
            let kc = KC.min(k - k0);
            if b_transposed {
                pack_b_t(b, brs, k0, kc, j0, nc, bpack);
            } else {
                pack_b_n(b, brs, k0, kc, j0, nc, bpack);
            }
            let mut i0 = 0;
            while i0 < m {
                let mc = MC.min(m - i0);
                if clip.live(i0 as isize, mc, j0 as isize, nc) {
                    pack_a(a, ars, i0, mc, k0, kc, apack);
                    macro_kernel(c, cs, i0, mc, j0, nc, kc, &*apack, &*bpack, alpha, clip);
                }
                i0 += MC;
            }
            k0 += KC;
        }
        j0 += NC;
    }
    arena_put(slot_a, abuf);
    arena_put(slot_b, bbuf);
}

/// `C += α·A·B` on row-major regions: `A` is `m×k` (row stride `ars`),
/// `B` is `k×n` (row stride `brs`), `C` is `m×n` (row stride `cs`).
/// Entries outside `clip` are left untouched.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn(
    c: &mut [f64],
    cs: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    b: &[f64],
    brs: usize,
    alpha: f64,
    clip: Clip,
) {
    gemm_driver(c, cs, m, n, k, a, ars, b, brs, alpha, clip, false);
}

/// `C += α·A·Bᵀ` with **both** operands row-major over `k`: `A` is `m×k`,
/// `B` is `n×k` (one row per output *column*), `C` is `m×n`. With
/// `A = B` and `Clip::Lower` this is the SYRK of the blocked Cholesky.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(
    c: &mut [f64],
    cs: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    b: &[f64],
    brs: usize,
    alpha: f64,
    clip: Clip,
) {
    gemm_driver(c, cs, m, n, k, a, ars, b, brs, alpha, clip, true);
}

/// Blocked forward substitution for `q` stacked row right-hand sides,
/// in place: row `r` of `x` becomes the solution of `L y = x[r]` where
/// `L` is the `nn×nn` lower triangle stored at `l` with row stride `ls`
/// (upper triangle never read). `x` rows have stride `xs ≥ nn`.
///
/// Column blocks of width [`TB`] are eliminated with [`gemm_nt`] against
/// the already-solved columns — mirrored into a private scratch buffer so
/// the in-place update reads and writes disjoint slices — then a scalar
/// triangle solve finishes the block. Per-row arithmetic is independent
/// of `q`, of the caller's row chunking, and of the thread count.
pub fn solve_lower_rows(l: &[f64], ls: usize, nn: usize, x: &mut [f64], xs: usize, q: usize) {
    if q == 0 || nn == 0 {
        return;
    }
    assert!(xs >= nn, "row stride shorter than the triangle");
    assert!(x.len() >= (q - 1) * xs + nn, "X region too short");
    assert!(l.len() >= (nn - 1) * ls + nn, "L region too short");
    // per-thread reusable mirror (stale contents never read: each block
    // is copied in before any elimination consumes it)
    let mut sbuf = arena_take(slot_mirror, q * nn);
    let solved = &mut sbuf[..q * nn];
    let mut j0 = 0;
    while j0 < nn {
        let j1 = (j0 + TB).min(nn);
        if j0 > 0 {
            // X[:, j0..j1] −= X[:, 0..j0] · L[j0..j1, 0..j0]ᵀ
            let c_end = (q - 1) * xs + j1;
            gemm_nt(
                &mut x[j0..c_end],
                xs,
                q,
                j1 - j0,
                j0,
                &*solved,
                nn,
                &l[j0 * ls..],
                ls,
                -1.0,
                Clip::None,
            );
        }
        // scalar triangle solve within the block
        for r in 0..q {
            let row = &mut x[r * xs..r * xs + j1];
            for j in j0..j1 {
                let lrow = j * ls;
                let mut acc = 0.0;
                for k in j0..j {
                    acc = l[lrow + k].mul_add(row[k], acc);
                }
                row[j] = (row[j] - acc) / l[lrow + j];
            }
        }
        // mirror the solved block so later GEMM updates read it from a
        // buffer disjoint from their write target
        for r in 0..q {
            solved[r * nn + j0..r * nn + j1].copy_from_slice(&x[r * xs + j0..r * xs + j1]);
        }
        j0 = j1;
    }
    arena_put(slot_mirror, sbuf);
}

/// Blocked backward substitution for `q` stacked row right-hand sides,
/// in place: row `r` of `x` becomes the solution of `Lᵀ y = x[r]`
/// (same storage conventions as [`solve_lower_rows`]). Column blocks are
/// processed right-to-left; the block grid is anchored at `nn`, so the
/// accumulation order is fixed by `nn` alone.
pub fn solve_lower_transpose_rows(
    l: &[f64],
    ls: usize,
    nn: usize,
    x: &mut [f64],
    xs: usize,
    q: usize,
) {
    if q == 0 || nn == 0 {
        return;
    }
    assert!(xs >= nn, "row stride shorter than the triangle");
    assert!(x.len() >= (q - 1) * xs + nn, "X region too short");
    assert!(l.len() >= (nn - 1) * ls + nn, "L region too short");
    let mut sbuf = arena_take(slot_mirror, q * nn);
    let solved = &mut sbuf[..q * nn];
    let mut j1 = nn;
    while j1 > 0 {
        let j0 = j1.saturating_sub(TB);
        if j1 < nn {
            // X[:, j0..j1] −= X[:, j1..nn] · L[j1..nn, j0..j1]
            let c_end = (q - 1) * xs + j1;
            gemm_nn(
                &mut x[j0..c_end],
                xs,
                q,
                j1 - j0,
                nn - j1,
                &solved[j1..],
                nn,
                &l[j1 * ls + j0..],
                ls,
                -1.0,
                Clip::None,
            );
        }
        for r in 0..q {
            let row = &mut x[r * xs..r * xs + j1];
            for j in (j0..j1).rev() {
                let mut acc = 0.0;
                for k in (j + 1)..j1 {
                    acc = l[k * ls + j].mul_add(row[k], acc);
                }
                row[j] = (row[j] - acc) / l[j * ls + j];
            }
        }
        for r in 0..q {
            solved[r * nn + j0..r * nn + j1].copy_from_slice(&x[r * xs + j0..r * xs + j1]);
        }
        j1 = j0;
    }
    arena_put(slot_mirror, sbuf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn randv(len: usize, rng: &mut Xoshiro256) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    fn naive_nn(m: usize, n: usize, k: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                let aik = a[i * k + kk];
                for j in 0..n {
                    c[i * n + j] += aik * b[kk * n + j];
                }
            }
        }
        c
    }

    fn naive_nt(m: usize, n: usize, k: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[j * k + kk];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn max_rel(got: &[f64], want: &[f64]) -> f64 {
        let scale = want.iter().fold(1.0f64, |s, v| s.max(v.abs()));
        got.iter().zip(want).map(|(g, w)| (g - w).abs()).fold(0.0, f64::max) / scale
    }

    #[test]
    fn gemm_nn_matches_naive_at_edge_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        for &(m, n, k) in
            &[(1usize, 1usize, 1usize), (3, 5, 2), (4, 8, 7), (5, 9, 3), (17, 13, 29), (40, 33, 65)]
        {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut c = vec![0.0; m * n];
            gemm_nn(&mut c, n, m, n, k, &a, k, &b, n, 1.0, Clip::None);
            let want = naive_nn(m, n, k, &a, &b);
            assert!(max_rel(&c, &want) < 1e-13, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn gemm_nt_matches_naive_and_respects_alpha() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for &(m, n, k) in &[(1usize, 2usize, 3usize), (6, 4, 9), (9, 17, 33), (33, 20, 5)] {
            let a = randv(m * k, &mut rng);
            let b = randv(n * k, &mut rng);
            let mut c = vec![1.0; m * n];
            gemm_nt(&mut c, n, m, n, k, &a, k, &b, k, -2.0, Clip::None);
            let want: Vec<f64> =
                naive_nt(m, n, k, &a, &b).iter().map(|v| 1.0 - 2.0 * v).collect();
            assert!(max_rel(&c, &want) < 1e-13, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn strided_subregions_work() {
        // operate on the interior of a larger buffer: strides > logical cols
        let mut rng = Xoshiro256::seed_from_u64(11);
        let (m, n, k) = (5usize, 6usize, 7usize);
        let (ars, brs, cs) = (11usize, 13usize, 9usize);
        let abuf = randv(m * ars, &mut rng);
        let bbuf = randv(k * brs, &mut rng);
        let mut cbuf = vec![0.0; m * cs];
        gemm_nn(&mut cbuf, cs, m, n, k, &abuf, ars, &bbuf, brs, 1.0, Clip::None);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += abuf[i * ars + kk] * bbuf[kk * brs + j];
                }
                assert!((cbuf[i * cs + j] - s).abs() < 1e-12, "({i},{j})");
            }
        }
        // columns beyond n untouched
        for i in 0..m {
            for j in n..cs {
                assert_eq!(cbuf[i * cs + j], 0.0);
            }
        }
    }

    #[test]
    fn clip_lower_writes_only_the_trapezoid() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let (m, k) = (37usize, 12usize);
        let a = randv(m * k, &mut rng);
        let mut c = vec![f64::NAN; m * m];
        // C = −A·Aᵀ on the lower triangle only (shift 0)
        for (i, v) in c.iter_mut().enumerate() {
            if i % m <= i / m {
                *v = 0.0;
            }
        }
        gemm_nt(&mut c, m, m, m, k, &a, k, &a, k, -1.0, Clip::Lower(0));
        let want = naive_nt(m, m, k, &a, &a);
        for i in 0..m {
            for j in 0..m {
                if j <= i {
                    assert!(
                        (c[i * m + j] + want[i * m + j]).abs() < 1e-12,
                        "lower ({i},{j})"
                    );
                } else {
                    assert!(c[i * m + j].is_nan(), "upper ({i},{j}) was written");
                }
            }
        }
    }

    #[test]
    fn clip_upper_with_shift() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let (m, n, k) = (9usize, 14usize, 6usize);
        let shift = 3isize;
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut c = vec![0.0; m * n];
        gemm_nn(&mut c, n, m, n, k, &a, k, &b, n, 1.0, Clip::Upper(shift));
        let want = naive_nn(m, n, k, &a, &b);
        for i in 0..m {
            for j in 0..n {
                if j as isize >= i as isize + shift {
                    assert!((c[i * n + j] - want[i * n + j]).abs() < 1e-12, "({i},{j})");
                } else {
                    assert_eq!(c[i * n + j], 0.0, "({i},{j}) below the clip was written");
                }
            }
        }
    }

    /// Well-conditioned lower triangle for solve tests.
    fn test_lower(nn: usize, rng: &mut Xoshiro256) -> Vec<f64> {
        let mut l = vec![0.0; nn * nn];
        for i in 0..nn {
            for j in 0..i {
                l[i * nn + j] = 0.3 * rng.normal() / (nn as f64).sqrt();
            }
            l[i * nn + i] = 2.0 + 0.1 * rng.normal().abs();
            // garbage above the diagonal must never be read
            for j in (i + 1)..nn {
                l[i * nn + j] = f64::NAN;
            }
        }
        l
    }

    #[test]
    fn solve_lower_rows_matches_scalar_solve() {
        let mut rng = Xoshiro256::seed_from_u64(19);
        for &nn in &[1usize, 5, 31, 32, 33, 97] {
            let l = test_lower(nn, &mut rng);
            let lm = {
                let mut m = crate::linalg::Matrix::zeros(nn, nn);
                for i in 0..nn {
                    for j in 0..=i {
                        m[(i, j)] = l[i * nn + j];
                    }
                }
                m
            };
            for &q in &[1usize, 4] {
                let b = randv(q * nn, &mut rng);
                let mut x = b.clone();
                solve_lower_rows(&l, nn, nn, &mut x, nn, q);
                for r in 0..q {
                    let mut want = b[r * nn..(r + 1) * nn].to_vec();
                    crate::linalg::solve_lower(&lm, &mut want);
                    for j in 0..nn {
                        let w = want[j];
                        assert!(
                            (x[r * nn + j] - w).abs() < 1e-11 * w.abs().max(1.0),
                            "nn={nn} q={q} row={r} col={j}: {} vs {w}",
                            x[r * nn + j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn solve_lower_transpose_rows_matches_scalar_solve() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        for &nn in &[1usize, 7, 32, 33, 97] {
            let l = test_lower(nn, &mut rng);
            let lm = {
                let mut m = crate::linalg::Matrix::zeros(nn, nn);
                for i in 0..nn {
                    for j in 0..=i {
                        m[(i, j)] = l[i * nn + j];
                    }
                }
                m
            };
            let q = 3;
            let b = randv(q * nn, &mut rng);
            let mut x = b.clone();
            solve_lower_transpose_rows(&l, nn, nn, &mut x, nn, q);
            for r in 0..q {
                let mut want = b[r * nn..(r + 1) * nn].to_vec();
                crate::linalg::solve_lower_transpose(&lm, &mut want);
                for j in 0..nn {
                    let w = want[j];
                    assert!(
                        (x[r * nn + j] - w).abs() < 1e-11 * w.abs().max(1.0),
                        "nn={nn} row={r} col={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn row_partition_invariance() {
        // the canonical-order contract: computing rows in two separate
        // calls gives bit-identical results to one call over all rows
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (m, n, k) = (23usize, 19usize, 300usize); // k spans two KC chunks
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut c_whole = vec![0.0; m * n];
        gemm_nn(&mut c_whole, n, m, n, k, &a, k, &b, n, 1.0, Clip::None);
        for split in [1usize, 7, 16] {
            let mut c_split = vec![0.0; m * n];
            let (top, bottom) = c_split.split_at_mut(split * n);
            gemm_nn(top, n, split, n, k, &a, k, &b, n, 1.0, Clip::None);
            gemm_nn(bottom, n, m - split, n, k, &a[split * k..], k, &b, n, 1.0, Clip::None);
            assert_eq!(c_split, c_whole, "split={split}");
        }
    }
}
