//! Levinson–Durbin solver for symmetric Toeplitz systems.
//!
//! Paper §3(b), footnote 7: regularly sampled data gives a Toeplitz
//! covariance matrix whose structure "could be exploited to accelerate the
//! inversion"; the authors chose not to so their code stays general. We
//! do: [`crate::gp::profiled::eval_value_with`] detects uniform time
//! grids and routes value-only likelihood evaluations through Levinson
//! (`O(n²)` solve + log-determinant versus the `O(n³)` Cholesky), and the
//! FITC backend ([`crate::gp::approx`]) uses the multi-RHS
//! [`ToeplitzSolver::solve_mat`] against its uniform inducing grid's
//! `C̃_mm`. The `O(n²)`-vs-`O(n³)` gap itself is measured in
//! `benches/ablations.rs`.

use super::Matrix;

/// Symmetric Toeplitz system solver built from the first column
/// `r = [r₀, r₁, …, r_{n−1}]` of the matrix `T` with `T_ij = r_{|i−j|}`.
///
/// Runs the classic Levinson–Durbin recursion, keeping the prediction-error
/// sequence, which gives the log-determinant for free:
/// `det T = Π_k E_k` where `E_k` are the successive innovation variances.
pub struct ToeplitzSolver {
    r: Vec<f64>,
    /// reflection (PARCOR) coefficients
    logdet: f64,
    /// innovation variances E_k (needed for solving too)
    forward: Vec<Vec<f64>>,
    evars: Vec<f64>,
}

impl ToeplitzSolver {
    /// Build the solver; fails if the recursion hits a non-positive
    /// innovation variance (matrix not positive definite).
    pub fn new(r: &[f64]) -> crate::Result<Self> {
        let n = r.len();
        anyhow::ensure!(n > 0, "empty Toeplitz spec");
        anyhow::ensure!(r[0] > 0.0, "T[0,0] must be positive");
        // Levinson recursion for the "forward" vectors a_k solving
        // T_k a_k = e_1 scaled; we store the standard formulation:
        // a_k = coefficients of the order-k forward predictor.
        let mut a = vec![0.0; n];
        let mut e = r[0];
        let mut logdet = r[0].ln();
        let mut forward: Vec<Vec<f64>> = Vec::with_capacity(n);
        forward.push(vec![]); // order 0: no coefficients
        let mut evars = Vec::with_capacity(n);
        evars.push(e);
        for k in 1..n {
            // reflection coefficient
            let mut acc = r[k];
            for j in 1..k {
                acc -= a[j] * r[k - j];
            }
            let kappa = acc / e;
            // update predictor a (order k)
            let mut new_a = vec![0.0; k + 1];
            new_a[k] = kappa;
            for j in 1..k {
                new_a[j] = a[j] - kappa * a[k - j];
            }
            a[..=k].copy_from_slice(&new_a);
            e *= 1.0 - kappa * kappa;
            anyhow::ensure!(
                e > 0.0 && e.is_finite(),
                "Toeplitz matrix not positive definite at order {k} (E = {e:.3e})"
            );
            logdet += e.ln();
            forward.push(a[1..=k].to_vec());
            evars.push(e);
        }
        Ok(Self { r: r.to_vec(), logdet, forward, evars })
    }

    /// Dimension.
    pub fn dim(&self) -> usize {
        self.r.len()
    }

    /// `ln det T`.
    pub fn logdet(&self) -> f64 {
        self.logdet
    }

    /// Solve `T x = b` in `O(n²)` using the stored predictors
    /// (Levinson general right-hand-side recursion).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(b.len(), n);
        let mut x = vec![0.0; n];
        x[0] = b[0] / self.r[0];
        for k in 1..n {
            // innovation: ε = b_k − Σ_{j<k} r_{k−j} x_j
            let mut eps = b[k];
            for j in 0..k {
                eps -= self.r[k - j] * x[j];
            }
            let alpha = eps / self.evars[k];
            // x ← [x, 0] + α · [−rev(a_k), 1]
            let a = &self.forward[k];
            // a has length k: coefficients a_1..a_k of the order-k predictor
            for j in 0..k {
                x[j] -= alpha * a[k - 1 - j];
            }
            x[k] = alpha;
        }
        x
    }

    /// Solve `T xᵢ = bᵢ` for a stack of right-hand sides held as the
    /// **rows** of `b` (the layout [`crate::linalg::Chol::half_solve_rows_with`]
    /// and the FITC `Q̃`-diagonal computation use): returns the matrix
    /// whose row `i` is `T⁻¹·row_i(b)`. `O(q·n²)` for `q` rows — each an
    /// independent Levinson back-substitution against the shared
    /// predictor/innovation tables, which are built once in `new`.
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let n = self.dim();
        assert_eq!(b.cols(), n, "RHS rows must have length {n}");
        let mut out = Matrix::zeros(b.rows(), n);
        for i in 0..b.rows() {
            let x = self.solve(b.row(i));
            out.row_mut(i).copy_from_slice(&x);
        }
        out
    }

    /// Materialise the dense matrix (test helper / cross-validation).
    pub fn dense(&self) -> Matrix {
        let n = self.dim();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = self.r[(i as isize - j as isize).unsigned_abs()];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Chol;
    use crate::rng::Xoshiro256;

    fn ar1_column(n: usize, rho: f64) -> Vec<f64> {
        (0..n).map(|k| rho.powi(k as i32)).collect()
    }

    #[test]
    fn solve_matches_cholesky() {
        let mut rng = Xoshiro256::seed_from_u64(41);
        for &n in &[2usize, 5, 20, 64] {
            let r = ar1_column(n, 0.7);
            let ts = ToeplitzSolver::new(&r).unwrap();
            let dense = ts.dense();
            let ch = Chol::factor(&dense).unwrap();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x_t = ts.solve(&b);
            let x_c = ch.solve(&b);
            for i in 0..n {
                assert!(
                    (x_t[i] - x_c[i]).abs() < 1e-9,
                    "n={n} i={i}: {} vs {}",
                    x_t[i],
                    x_c[i]
                );
            }
        }
    }

    #[test]
    fn logdet_matches_cholesky() {
        for &n in &[3usize, 10, 50] {
            let r = ar1_column(n, 0.5);
            let ts = ToeplitzSolver::new(&r).unwrap();
            let ch = Chol::factor(&ts.dense()).unwrap();
            assert!(
                (ts.logdet() - ch.logdet()).abs() < 1e-9 * ch.logdet().abs().max(1.0),
                "n={n}: {} vs {}",
                ts.logdet(),
                ch.logdet()
            );
        }
    }

    #[test]
    fn identity_case() {
        let ts = ToeplitzSolver::new(&[1.0, 0.0, 0.0, 0.0]).unwrap();
        let b = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(ts.solve(&b), b.to_vec());
        assert_eq!(ts.logdet(), 0.0);
    }

    #[test]
    fn rejects_indefinite() {
        // r1 > r0 means the 2×2 leading minor r0² − r1² is negative, i.e.
        // the lag-1 correlation ρ = r1/r0 = 1.2 violates |ρ| ≤ 1 — the
        // recursion must hit a non-positive innovation variance and fail.
        assert!(ToeplitzSolver::new(&[1.0, 1.2]).is_err());
    }

    #[test]
    fn solve_mat_matches_rowwise_solve() {
        let mut rng = Xoshiro256::seed_from_u64(43);
        let n = 24;
        let ts = ToeplitzSolver::new(&ar1_column(n, 0.6)).unwrap();
        let q = 5;
        let mut b = Matrix::zeros(q, n);
        for i in 0..q {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let x = ts.solve_mat(&b);
        for i in 0..q {
            assert_eq!(x.row(i), &ts.solve(b.row(i))[..], "row {i}");
        }
        // and against the dense factorisation
        let ch = Chol::factor(&ts.dense()).unwrap();
        for i in 0..q {
            let xc = ch.solve(b.row(i));
            for j in 0..n {
                assert!((x[(i, j)] - xc[j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }
}
