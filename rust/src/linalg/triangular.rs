//! Triangular solves against a lower factor stored in a full square
//! matrix (upper triangle ignored).
//!
//! These are the scalar single-RHS sweeps (one FMA `dot`/`axpy` per row);
//! the multi-RHS hot paths use the blocked
//! [`crate::linalg::micro::solve_lower_rows`] family instead.

use super::{axpy, dot, Matrix};

/// Solve `L x = b` in place (`b` becomes `x`), `L` lower triangular.
pub fn solve_lower(l: &Matrix, b: &mut [f64]) {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let c = l.cols();
    let data = l.as_slice();
    for i in 0..n {
        let row = i * c;
        // dot of the solved prefix with L's row — contiguous, vectorises
        let acc = dot(&data[row..row + i], &b[..i]);
        b[i] = (b[i] - acc) / data[row + i];
    }
}

/// Solve `Lᵀ x = b` in place, `L` lower triangular (so `Lᵀ` is upper).
///
/// Implemented as a column-oriented backward sweep so all inner accesses
/// still walk `L`'s rows contiguously.
pub fn solve_lower_transpose(l: &Matrix, b: &mut [f64]) {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let c = l.cols();
    let data = l.as_slice();
    for i in (0..n).rev() {
        let row = i * c;
        let xi = b[i] / data[row + i];
        b[i] = xi;
        // eliminate x_i from all earlier equations: b[k] -= L[i,k] * x_i
        axpy(-xi, &data[row..row + i], &mut b[..i]);
    }
}

/// Solve `U x = b` in place for a genuinely upper-triangular `U`
/// (used by the small-m LU in Hessian determinant work).
pub fn solve_upper(u: &Matrix, b: &mut [f64]) {
    let n = u.rows();
    debug_assert_eq!(b.len(), n);
    let c = u.cols();
    let data = u.as_slice();
    for i in (0..n).rev() {
        let row = i * c;
        let acc = dot(&data[row + i + 1..row + n], &b[i + 1..n]);
        b[i] = (b[i] - acc) / data[row + i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lower_solve_exact() {
        // L = [[2,0],[1,3]], b = [4, 7] → x = [2, 5/3]
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let mut b = vec![4.0, 7.0];
        solve_lower(&l, &mut b);
        assert!((b[0] - 2.0).abs() < 1e-15);
        assert!((b[1] - 5.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn lower_transpose_solve_exact() {
        // Lᵀ = [[2,1],[0,3]], b = [5, 6] → x₁ = 2, x₀ = (5-2)/2 = 1.5
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let mut b = vec![5.0, 6.0];
        solve_lower_transpose(&l, &mut b);
        assert!((b[1] - 2.0).abs() < 1e-15);
        assert!((b[0] - 1.5).abs() < 1e-15);
    }

    #[test]
    fn upper_solve_exact() {
        let u = Matrix::from_rows(&[&[2.0, 1.0], &[0.0, 4.0]]);
        let mut b = vec![4.0, 8.0];
        solve_upper(&u, &mut b);
        assert!((b[1] - 2.0).abs() < 1e-15);
        assert!((b[0] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn garbage_upper_triangle_is_ignored() {
        let mut l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        l[(0, 1)] = f64::NAN; // must never be read
        let mut b = vec![4.0, 7.0];
        solve_lower(&l, &mut b);
        assert!(b.iter().all(|x| x.is_finite()));
        let mut b = vec![5.0, 6.0];
        solve_lower_transpose(&l, &mut b);
        assert!(b.iter().all(|x| x.is_finite()));
    }
}
