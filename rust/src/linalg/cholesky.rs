//! Blocked Cholesky factorisation — the `O(n³)` hot path of the paper.
//!
//! `K = L Lᵀ` with `L` lower triangular. The factorisation is
//! *right-looking* and blocked: for each diagonal block we factor a small
//! `nb×nb` panel unblocked, triangular-solve the panel below it, and then
//! apply a symmetric rank-`nb` update to the trailing submatrix. The
//! trailing update is where ~all the FLOPs are; it is written as a
//! register-blocked `C -= A Bᵀ` micro-kernel over row-major storage that
//! the compiler auto-vectorises.
//!
//! ## Streaming primitives
//!
//! The serving layer ([`crate::gp::serve`]) amortises one factorisation
//! across many queries and data arrivals, so [`Chol`] also supports
//! `O(n²)` *incremental* maintenance: [`Chol::extend`] appends one
//! observation (bordered factorisation — one triangular solve plus a
//! square root), [`Chol::rank1_update`] / [`Chol::rank1_downdate`]
//! apply `K ± xxᵀ` via Givens / hyperbolic sweeps (LINPACK
//! `dchud`/`dchdd`), and — the sliding-window direction —
//! [`Chol::remove_row`] / [`Chol::shrink_front`] *delete* observations
//! via the bordered-complement restore: the deleted point's subdiagonal
//! column seeds a rank-1 update sweep on the trailing block, so the
//! remaining factor is exactly the factor of the covariance with that
//! row/column struck out. All of them maintain the cached
//! log-determinant.
//!
//! ## Kernel structure and parallelism
//!
//! The panel TRSM and the trailing SYRK both run on the packed
//! [`super::micro`] kernels: every iteration copies the sub-diagonal
//! panel into a contiguous scratch buffer, solves it there against the
//! diagonal block ([`crate::linalg::micro::solve_lower_rows`]), writes it
//! back, and then applies the rank-`nb` trailing update as a clipped
//! `C −= P·Pᵀ` GEMM ([`crate::linalg::micro::gemm_nt`] with
//! `Clip::Lower`) reading the shared packed panel. With a multi-thread
//! [`ExecutionContext`] the row tiles of both stages are partitioned
//! across workers (SYRK tiles weighted by their triangular cost); the
//! disjointness is expressed through `split_at_mut`, no `unsafe`. The
//! micro-kernels' per-entry accumulation order is fixed by the global
//! block grids, so the factor is **bit-identical for any thread count**.

use super::{micro, solve_lower, solve_lower_transpose, Matrix};
use crate::runtime::exec::{
    even_bounds, for_row_chunks, for_row_chunks_multi, weighted_bounds, ExecutionContext,
    PAR_MIN_WORK,
};
use std::fmt;

/// Block size for the panel factorisation. 48–96 all perform similarly on
/// the benchmark machine; 64 keeps the panel (64·n doubles) in L2.
const NB: usize = 64;

/// Minimum trailing rows per worker before a parallel dispatch pays for
/// its scoped-thread spawns.
const PAR_MIN_ROWS: usize = 48;

/// Error: matrix was not positive definite.
#[derive(Debug, Clone, Copy)]
pub struct CholError {
    /// Index of the pivot that failed.
    pub pivot: usize,
    /// Value of the failed pivot.
    pub value: f64,
}

impl fmt::Display for CholError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "matrix not positive definite: pivot {} = {:.3e} <= 0",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholError {}

/// A computed Cholesky factorisation with the operations the GP layer
/// needs: solves, log-determinant, quadratic forms.
#[derive(Debug, Clone)]
pub struct Chol {
    /// Lower-triangular factor (upper triangle is garbage, never read).
    l: Matrix,
    logdet: f64,
}

impl Chol {
    /// Factor a symmetric positive-definite matrix (serial).
    ///
    /// Only the lower triangle of `k` is read.
    pub fn factor(k: &Matrix) -> Result<Self, CholError> {
        Self::factor_with(k, &ExecutionContext::seq())
    }

    /// Factor with an explicit thread budget.
    pub fn factor_with(k: &Matrix, ctx: &ExecutionContext) -> Result<Self, CholError> {
        Self::factor_owned_with(k.clone(), ctx)
    }

    /// Factor, consuming the input matrix (no copy) — used on the hot path
    /// where the covariance buffer is rebuilt every iteration anyway.
    pub fn factor_owned(k: Matrix) -> Result<Self, CholError> {
        Self::factor_owned_with(k, &ExecutionContext::seq())
    }

    /// Owned factorisation with an explicit thread budget.
    pub fn factor_owned_with(mut k: Matrix, ctx: &ExecutionContext) -> Result<Self, CholError> {
        factor_in_place_ctx(&mut k, ctx)?;
        let n = k.rows();
        let mut logdet = 0.0;
        for i in 0..n {
            logdet += k[(i, i)].ln();
        }
        Ok(Self { l: k, logdet: 2.0 * logdet })
    }

    /// Owned factorisation that hands the buffer back on failure.
    ///
    /// Identical arithmetic to [`Chol::factor_owned_with`] (bit-identical
    /// success path), but a failed pivot returns the clobbered matrix
    /// alongside the error instead of dropping it. The factorisation only
    /// writes the diagonal and strict lower triangle, so a caller that
    /// saved the `O(n)` diagonal can repair the buffer from the untouched
    /// upper triangle ([`Matrix::mirror_upper_to_lower`]) and retry —
    /// the jitter-escalation ladder of [`crate::gp::profiled`] does
    /// exactly this, without re-allocating or re-assembling `K̃`.
    pub fn factor_owned_recoverable_with(
        mut k: Matrix,
        ctx: &ExecutionContext,
    ) -> Result<Self, (Matrix, CholError)> {
        match factor_in_place_ctx(&mut k, ctx) {
            Ok(()) => {
                let n = k.rows();
                let mut logdet = 0.0;
                for i in 0..n {
                    logdet += k[(i, i)].ln();
                }
                Ok(Self { l: k, logdet: 2.0 * logdet })
            }
            Err(e) => Err((k, e)),
        }
    }

    /// Reassemble a factorisation from its raw parts — the persistence
    /// path ([`crate::coordinator::TrainedModel`] save/load). The caller
    /// guarantees `l` is a valid lower-triangular Cholesky factor (the
    /// upper triangle is never read) and that `logdet` is its
    /// log-determinant. `logdet` is taken verbatim rather than recomputed
    /// because the incremental maintenance above accumulates it in a
    /// specific order — restoring the stored value keeps a save→load
    /// round trip bit-identical.
    pub fn from_parts(l: Matrix, logdet: f64) -> Self {
        assert_eq!(l.rows(), l.cols(), "factor must be square");
        Self { l, logdet }
    }

    /// Reassemble a factorisation straight from a **packed lower
    /// triangle** (row-major, row `i` contributing `i + 1` doubles) —
    /// the zero-copy artifact path ([`crate::coordinator::artifact`]
    /// format v4): the borrowed view's factor block is scattered into
    /// the dense triangle in one pass, with no intermediate per-row
    /// `Vec` allocations. Same caller contract as [`Chol::from_parts`].
    pub fn from_packed_lower(packed: &[f64], n: usize, logdet: f64) -> Self {
        assert_eq!(packed.len(), n * (n + 1) / 2, "packed triangle length");
        let mut l = Matrix::zeros(n, n);
        let mut off = 0;
        for i in 0..n {
            l.row_mut(i)[..=i].copy_from_slice(&packed[off..off + i + 1]);
            off += i + 1;
        }
        Self { l, logdet }
    }

    /// Dimension `n`.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// `ln det K = 2 Σ ln L_ii` — the determinant term of eq. (2.5).
    pub fn logdet(&self) -> f64 {
        self.logdet
    }

    /// The lower-triangular factor.
    pub fn factor_matrix(&self) -> &Matrix {
        &self.l
    }

    /// Solve `K x = b` (two triangular solves).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        solve_lower(&self.l, &mut x);
        solve_lower_transpose(&self.l, &mut x);
        x
    }

    /// Hager-style 1-norm condition estimate `κ₁(K) ≈ ‖K‖₁·‖K⁻¹‖₁` of
    /// the factored matrix, in `O(n²)` — a handful of `L(Lᵀx)` products
    /// and cached-factor solves, no refactorisation and no
    /// eigendecomposition. This is the per-refresh conditioning probe of
    /// the serving layer's factor-health monitoring; `f64::INFINITY`
    /// signals a non-finite factor.
    pub fn cond_1est(&self) -> f64 {
        let n = self.dim();
        if n == 0 {
            return 1.0;
        }
        let norm_a = super::sym_one_norm_est(n, |x| self.apply(x));
        let norm_ainv = super::sym_one_norm_est(n, |x| self.solve(x));
        norm_a * norm_ainv
    }

    /// `K·x` reconstituted from the factor: `L·(Lᵀ·x)`. Reads only the
    /// lower triangle (the stored upper triangle is garbage).
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let n = self.dim();
        debug_assert_eq!(x.len(), n);
        // u = Lᵀ x: u_i = Σ_{k≥i} L[k][i]·x[k]
        let mut u = vec![0.0; n];
        for k in 0..n {
            let row = &self.l.row(k)[..=k];
            let xk = x[k];
            for (i, &lki) in row.iter().enumerate() {
                u[i] = lki.mul_add(xk, u[i]);
            }
        }
        // y = L u: y_i = Σ_{k≤i} L[i][k]·u[k]
        (0..n).map(|i| super::dot(&self.l.row(i)[..=i], &u[..=i])).collect()
    }

    /// Solve `L w = b` only (half-solve; `wᵀw = bᵀ K⁻¹ b`).
    pub fn half_solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        solve_lower(&self.l, &mut x);
        x
    }

    /// Quadratic form `bᵀ K⁻¹ b` via one triangular solve.
    pub fn inv_quad(&self, b: &[f64]) -> f64 {
        let w = self.half_solve(b);
        super::dot(&w, &w)
    }

    /// Solve `K X = B` for a multi-column right-hand side, column-blocked.
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        self.solve_mat_with(b, &ExecutionContext::seq())
    }

    /// Multi-RHS solve: transpose to one RHS per row (cache-blocked
    /// transpose), run both blocked multi-row TRSMs
    /// ([`micro::solve_lower_rows`] / [`micro::solve_lower_transpose_rows`])
    /// with the rows distributed over the context's threads, and
    /// transpose back. Per-row arithmetic is independent of the row
    /// partition, so results are bit-identical for any thread count.
    pub fn solve_mat_with(&self, b: &Matrix, ctx: &ExecutionContext) -> Matrix {
        assert_eq!(b.rows(), self.dim());
        let n = self.dim();
        let m = b.cols();
        let mut out = b.transpose();
        if n == 0 || m == 0 {
            return out.transpose();
        }
        // below ~256 a column's two O(n²) sweeps are µs-scale — spawning
        // threads costs more than it saves (same dispatch-cutoff idea as
        // the factorisation's PAR_MIN_ROWS)
        let jobs = if n < 256 { 1 } else { ctx.threads().min(m.max(1)) };
        let bounds = even_bounds(0, m, jobs);
        let ld = self.l.as_slice();
        let ls = self.l.cols();
        for_row_chunks(out.as_mut_slice(), n, &bounds, ctx, |chunk, c0, c1| {
            let q = c1 - c0;
            micro::solve_lower_rows(ld, ls, n, chunk, n, q);
            micro::solve_lower_transpose_rows(ld, ls, n, chunk, n, q);
        });
        out.transpose()
    }

    /// Solve `L w = b` for several right-hand-side rows at once: `b` is
    /// `q×n` row-major with one RHS per **row**, solved in place through
    /// the blocked multi-row TRSM ([`micro::solve_lower_rows`]). Rows are
    /// distributed over the context's threads; per-row arithmetic is
    /// independent of the batch size, the row partition and the thread
    /// count, so a `q`-row batch is bit-identical to `q` single-row
    /// batches and to any threaded run. This is the multi-RHS TRSM of the
    /// serving layer's batched predictive variance (and of
    /// [`crate::gp::predict::predict`], which shares it so pointwise and
    /// batched predictions agree bitwise).
    pub fn half_solve_rows_with(&self, b: &mut Matrix, ctx: &ExecutionContext) {
        let n = self.dim();
        assert_eq!(b.cols(), n, "RHS rows must have length n");
        let q = b.rows();
        if q == 0 || n == 0 {
            return;
        }
        // gate on total batch size, not n alone: a large batch over a
        // small factor is still O(q n²) of work worth distributing
        let jobs =
            if q * n < PAR_MIN_WORK { 1 } else { ctx.threads().min(q.max(1)) };
        let bounds = even_bounds(0, q, jobs);
        let ld = self.l.as_slice();
        let ls = self.l.cols();
        for_row_chunks(b.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
            micro::solve_lower_rows(ld, ls, n, chunk, n, r1 - r0);
        });
    }

    /// Grow the factorisation by one observation in `O(n²)` — the
    /// streaming-serving primitive. Given the cross-covariances `cross`
    /// (`k(t_new, t_i)` for the existing `n` points) and the new
    /// diagonal entry `diag = k(0) + σ_n²`, the factor of the bordered
    /// matrix `[[K, k], [kᵀ, d]]` is `[[L, 0], [wᵀ, l₂₂]]` with
    /// `w = L⁻¹k` (one triangular solve) and `l₂₂ = √(d − wᵀw)`.
    ///
    /// The first `n` rows of the factor are untouched — exactly what a
    /// cold refactorisation would produce for them — so repeated extends
    /// stay within rounding of a from-scratch factor of the grown matrix
    /// (asserted at 1e-10 in `rust/tests/serving.rs`).
    ///
    /// Errors when the bordered matrix is not positive definite
    /// (`d ≤ wᵀw`, e.g. a duplicate input point with no jitter).
    pub fn extend(&mut self, cross: &[f64], diag: f64) -> Result<(), CholError> {
        let n = self.dim();
        assert_eq!(cross.len(), n, "cross-covariance length mismatch");
        let mut w = cross.to_vec();
        solve_lower(&self.l, &mut w);
        let d = diag - super::dot(&w, &w);
        self.extend_solved(&w, d)
    }

    /// [`Chol::extend`] with the triangular solve already done: `w` is
    /// `L⁻¹k` and `d` the Schur-complement pivot `diag − wᵀw`. Callers
    /// that computed `w`/`d` anyway (e.g. a predictive-variance check
    /// before committing the append — the serving router's pivot
    /// pre-check) skip the second `O(n²)` solve.
    pub fn extend_solved(&mut self, w: &[f64], d: f64) -> Result<(), CholError> {
        let n = self.dim();
        assert_eq!(w.len(), n, "solved border length mismatch");
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError { pivot: n, value: d });
        }
        let l22 = d.sqrt();
        // regrow the row-major storage (cols changes, so rows must move;
        // an O(n²) copy — same order as the solve above)
        let mut grown = Matrix::zeros(n + 1, n + 1);
        for i in 0..n {
            // only the lower triangle is live; the rest stays zero
            grown.row_mut(i)[..=i].copy_from_slice(&self.l.row(i)[..=i]);
        }
        grown.row_mut(n)[..n].copy_from_slice(w);
        grown[(n, n)] = l22;
        self.l = grown;
        self.logdet += 2.0 * l22.ln();
        Ok(())
    }

    /// Rank-1 **update** in place: the factor of `K + x xᵀ` in `O(n²)`
    /// (LINPACK `dchud`-style Givens sweep). `x` is consumed as scratch.
    pub fn rank1_update(&mut self, x: &mut [f64]) {
        let n = self.dim();
        assert_eq!(x.len(), n);
        rank1_update_block(&mut self.l, 0, x);
        self.recompute_logdet();
    }

    /// Rank-1 **downdate**: the factor of `K − x xᵀ` in `O(n²)`
    /// (hyperbolic-rotation sweep). `x` is consumed as scratch.
    ///
    /// The error is **recoverable**: the sweep runs on a scratch copy and
    /// only commits when every pivot stays positive *and* every computed
    /// entry stays finite, so on failure the live factor (and its cached
    /// log-determinant) are exactly what they were before the call. Two
    /// failure modes are rejected: an indefinite downdate (`d ≤ 0` at
    /// some pivot) and a near-singular trailing block, where a pivot is
    /// still positive but so tiny that `1/cos` overflows the column —
    /// committing that sweep would poison the factor with `inf`/`NaN`.
    /// The reported `value` is the offending pivot's Schur complement
    /// (possibly a tiny positive number in the near-singular case).
    pub fn rank1_downdate(&mut self, x: &mut [f64]) -> Result<(), CholError> {
        let n = self.dim();
        assert_eq!(x.len(), n);
        let mut scratch = self.l.clone();
        let c = scratch.cols();
        let data = scratch.as_mut_slice();
        for k in 0..n {
            let lkk = data[k * c + k];
            let d = lkk * lkk - x[k] * x[k];
            if d <= 0.0 || !d.is_finite() {
                return Err(CholError { pivot: k, value: d });
            }
            let r = d.sqrt();
            let co = r / lkk;
            let si = x[k] / lkk;
            data[k * c + k] = r;
            for i in (k + 1)..n {
                let lik = (data[i * c + k] - si * x[i]) / co;
                if !lik.is_finite() {
                    return Err(CholError { pivot: k, value: d });
                }
                data[i * c + k] = lik;
                x[i] = co * x[i] - si * lik;
            }
        }
        self.l = scratch;
        self.recompute_logdet();
        Ok(())
    }

    /// Delete observation `i` from the factorisation in `O((n−i)²)` — the
    /// arbitrary-index eviction primitive. Writing the factored matrix as
    ///
    /// ```text
    /// L = [[L₁₁, 0,   0  ],        K = [[K₁₁, k₁,  K₃₁ᵀ],
    ///      [l₂₁ᵀ, l₂₂, 0 ],             [k₁ᵀ, k₂₂, k₃₂ᵀ],
    ///      [L₃₁, l₃₂, L₃₃]]             [K₃₁, k₃₂, K₃₃ ]]
    /// ```
    ///
    /// with row `i` the middle block, the covariance with row/column `i`
    /// struck out has the bordered-complement factor `[[L₁₁, 0], [L₃₁,
    /// L̃₃₃]]` where `L̃₃₃L̃₃₃ᵀ = L₃₃L₃₃ᵀ + l₃₂l₃₂ᵀ` — i.e. the deleted
    /// point's subdiagonal column seeds one rank-1 **update** sweep on
    /// the trailing block (updates cannot fail, so deletion is
    /// infallible). Rows above `i` are untouched; the cached logdet is
    /// recomputed from the new diagonal.
    pub fn remove_row(&mut self, i: usize) {
        let n = self.dim();
        assert!(i < n, "remove_row({i}) out of range for dim {n}");
        let mut x: Vec<f64> = ((i + 1)..n).map(|r| self.l[(r, i)]).collect();
        let mut out = Matrix::zeros(n - 1, n - 1);
        for r in 0..i {
            out.row_mut(r)[..=r].copy_from_slice(&self.l.row(r)[..=r]);
        }
        for r in (i + 1)..n {
            let nr = r - 1;
            let src = self.l.row(r);
            out.row_mut(nr)[..i].copy_from_slice(&src[..i]);
            // old columns i+1..=r land at i..=nr (one step left)
            out.row_mut(nr)[i..=nr].copy_from_slice(&src[i + 1..=r]);
        }
        rank1_update_block(&mut out, i, &mut x);
        self.l = out;
        self.recompute_logdet();
    }

    /// Drop the `k` **oldest** observations (the leading rows/columns) in
    /// `O(k·(n−k)²)` — the sliding-window eviction primitive. The kept
    /// trailing block `L₂₂` satisfies `K₂₂ = L₂₁L₂₁ᵀ + L₂₂L₂₂ᵀ`, so the
    /// factor of the trailing covariance is `L₂₂` updated by one rank-1
    /// sweep per dropped column of `L₂₁` (order-independent up to
    /// rounding; cannot fail). Equivalent to `k` calls of
    /// [`Chol::remove_row`]`(0)` with a single storage copy.
    pub fn shrink_front(&mut self, k: usize) {
        let n = self.dim();
        assert!(k <= n, "shrink_front({k}) out of range for dim {n}");
        if k == 0 {
            return;
        }
        let m = n - k;
        let mut out = Matrix::zeros(m, m);
        for r in 0..m {
            out.row_mut(r)[..=r].copy_from_slice(&self.l.row(r + k)[k..=r + k]);
        }
        for j in 0..k {
            let mut x: Vec<f64> = (k..n).map(|r| self.l[(r, j)]).collect();
            rank1_update_block(&mut out, 0, &mut x);
        }
        self.l = out;
        self.recompute_logdet();
    }

    /// Refresh the cached log-determinant from the factor diagonal.
    fn recompute_logdet(&mut self) {
        let n = self.dim();
        let c = self.l.cols();
        let data = self.l.as_slice();
        let mut logdet = 0.0;
        for i in 0..n {
            logdet += data[i * c + i].ln();
        }
        self.logdet = 2.0 * logdet;
    }

    /// Explicit inverse `K⁻¹ = L⁻ᵀ L⁻¹` (dpotri-style, serial).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): this used to solve `K X = I`
    /// column by column (≈ 2n³ flops, column-strided access). It now does
    /// a triangular inversion into `U = (L⁻¹)ᵀ` — whose recurrence walks
    /// both operands along contiguous rows — followed by the symmetric
    /// product `W_ab = Σ_k U_ak U_bk`, for ≈ n³/2 flops total with
    /// sequential access. ~5× faster at n ≈ 2000.
    pub fn inverse(&self) -> Matrix {
        self.inverse_with(&ExecutionContext::seq())
    }

    /// Explicit inverse with both `O(n³)` stages row-parallel and on the
    /// packed [`micro`] kernels: every row of `U` depends only on `L`,
    /// and every row of the symmetric product depends only on `U`, so
    /// each stage partitions its output rows (weighted by their
    /// triangular cost) across the context.
    ///
    /// **Stage 1** (`U = (L⁻¹)ᵀ`): row `j` of `U` is the solution of
    /// `L x = e_j`, whose leading `j` components are exactly zero — so
    /// rows are solved in groups of `INV_RB` through the blocked
    /// multi-row TRSM ([`micro::solve_lower_rows`]) on the trailing
    /// subtriangle at each group's origin, with unit-vector right-hand
    /// sides. The group grid is anchored at row 0, so a row's arithmetic
    /// depends only on its own (global) group — bit-identical for any
    /// thread count or partition. This lifted the last scalar `O(n³)`
    /// recurrence onto the tiled kernels.
    ///
    /// **Stage 2** (`W = U·Uᵀ`) runs on the clipped [`micro::gemm_nt`]
    /// kernel, column-blocked so each block's `k` range starts at the
    /// block edge (entries with `k < b` contribute exact zeros from `U`'s
    /// lower triangle); that block grid is global too.
    pub fn inverse_with(&self, ctx: &ExecutionContext) -> Matrix {
        /// Column-block width of the `W = U·Uᵀ` stage: the wasted
        /// `k ∈ [b₀, b)` zero-work per block is `≤ INV_CB/2` of the
        /// `n − b₀` real depth.
        const INV_CB: usize = 128;
        /// Row-group width of the stage-1 triangular inversion. Groups
        /// are anchored on the global `j = 0` grid (part of the
        /// accumulation-order contract); within a group the `≤ INV_RB`
        /// leading columns of zero right-hand-side cost are the only
        /// wasted work.
        const INV_RB: usize = 32;
        let n = self.dim();
        if n == 0 {
            return Matrix::zeros(0, 0);
        }
        let c = self.l.cols();
        let ld = self.l.as_slice();
        let jobs = ctx.threads().min((n / PAR_MIN_ROWS).max(1));
        // U[j][i] = (L⁻¹)[i][j] for i ≥ j (row-major upper triangle):
        // row j of U solves L x = e_j on the subtriangle at its group's
        // origin (components before the group are exact zeros, and the
        // solve reproduces the zeros between the origin and j exactly).
        let mut u = Matrix::zeros(n, n);
        {
            let nblocks = (n + INV_RB - 1) / INV_RB;
            // partition whole groups across workers, weighted by each
            // group's O((n − j)²) solve cost
            let block_bounds = weighted_bounds(0, nblocks, jobs.min(nblocks), |b| {
                let j0 = b * INV_RB;
                let j1 = (j0 + INV_RB).min(n);
                (j0..j1).map(|j| ((n - j) as f64) * ((n - j) as f64)).sum()
            });
            let bounds: Vec<usize> =
                block_bounds.iter().map(|&b| (b * INV_RB).min(n)).collect();
            for_row_chunks(u.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
                let mut b0 = r0;
                while b0 < r1 {
                    let b1 = (b0 + INV_RB).min(r1);
                    for j in b0..b1 {
                        chunk[(j - r0) * n + j] = 1.0;
                    }
                    let x0 = (b0 - r0) * n + b0;
                    let x1 = (b1 - 1 - r0) * n + n;
                    micro::solve_lower_rows(
                        &ld[b0 * c + b0..],
                        c,
                        n - b0,
                        &mut chunk[x0..x1],
                        n,
                        b1 - b0,
                    );
                    b0 = b1;
                }
            });
        }
        // W[a][b] = Σ_{k ≥ max(a,b)} U[a][k] U[b][k]; fill the upper
        // triangle row-parallel (each worker sweeps the live column
        // blocks of its rows), then mirror.
        let mut w = Matrix::zeros(n, n);
        {
            let ud = u.as_slice();
            let bounds = weighted_bounds(0, n, jobs, |a| ((n - a) as f64) * ((n - a) as f64));
            for_row_chunks(w.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
                let m_rows = r1 - r0;
                let mut b0 = 0;
                while b0 < n {
                    let b1 = (b0 + INV_CB).min(n);
                    if b1 > r0 {
                        // W[r0..r1, b0..b1] += U[r0..r1, b0..]·U[b0..b1, b0..]ᵀ
                        micro::gemm_nt(
                            &mut chunk[b0..(m_rows - 1) * n + b1],
                            n,
                            m_rows,
                            b1 - b0,
                            n - b0,
                            &ud[r0 * n + b0..],
                            n,
                            &ud[b0 * n + b0..],
                            n,
                            1.0,
                            micro::Clip::Upper(r0 as isize - b0 as isize),
                        );
                    }
                    b0 = b1;
                }
            });
        }
        w.mirror_upper_to_lower();
        w
    }
}

/// LINPACK `dchud` Givens sweep on the trailing block of a lower factor:
/// replaces `L[off.., off..]` with the factor of
/// `L[off.., off..]·L[off.., off..]ᵀ + x xᵀ`, leaving rows/columns before
/// `off` untouched. `x` (length `rows − off`) is consumed as scratch.
/// Shared by [`Chol::rank1_update`] (`off = 0`) and the deletion
/// primitives, whose update acts only on the block trailing the removed
/// row. Cannot fail: every new pivot is `√(l²+x²) ≥ l > 0`.
fn rank1_update_block(l: &mut Matrix, off: usize, x: &mut [f64]) {
    let n = l.rows();
    debug_assert_eq!(x.len(), n - off);
    let c = l.cols();
    let data = l.as_mut_slice();
    for k in off..n {
        let xk = x[k - off];
        let lkk = data[k * c + k];
        let r = (lkk * lkk + xk * xk).sqrt();
        let co = r / lkk;
        let si = xk / lkk;
        data[k * c + k] = r;
        for i in (k + 1)..n {
            let lik = (data[i * c + k] + si * x[i - off]) / co;
            data[i * c + k] = lik;
            x[i - off] = co * x[i - off] - si * lik;
        }
    }
}

/// Unblocked lower Cholesky on the leading `n×n` of `a` (for panels).
fn factor_unblocked(a: &mut Matrix, off: usize, n: usize) -> Result<(), CholError> {
    let c = a.cols();
    for j in off..off + n {
        // diagonal
        let row_j = j * c;
        let d = {
            let data = a.as_slice();
            data[row_j + j] - super::dot(&data[row_j + off..row_j + j], &data[row_j + off..row_j + j])
        };
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError { pivot: j, value: d });
        }
        let d = d.sqrt();
        a.as_mut_slice()[row_j + j] = d;
        let inv_d = 1.0 / d;
        // column below the diagonal
        for i in (j + 1)..off + n {
            let row_i = i * c;
            let s = {
                let data = a.as_slice();
                // s = a[i,j] − Σ_k a[i,k]·a[j,k]
                data[row_i + j]
                    - super::dot(&data[row_i + off..row_i + j], &data[row_j + off..row_j + j])
            };
            a.as_mut_slice()[row_i + j] = s * inv_d;
        }
    }
    Ok(())
}

/// In-place blocked lower Cholesky on the packed micro-kernels, with
/// both the panel TRSM and the trailing SYRK parallelised over the
/// context (see the module docs). Only the lower triangle is referenced.
pub(crate) fn factor_in_place_ctx(
    a: &mut Matrix,
    ctx: &ExecutionContext,
) -> Result<(), CholError> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "Cholesky requires a square matrix");
    let c = n;
    let mut panel: Vec<f64> = Vec::new();
    let mut off = 0;
    while off < n {
        let nb = NB.min(n - off);
        // 1. factor the diagonal panel
        factor_unblocked(a, off, nb)?;
        let t0 = off + nb;
        if t0 < n {
            let rows = n - t0;
            let jobs = ctx.threads().min((rows / PAR_MIN_ROWS).max(1));
            panel.resize(rows * nb, 0.0);
            // 2. TRSM: each worker copies its rows' panel columns into
            // the contiguous scratch, solves them there against the
            // (read-only) diagonal block, and writes them back
            {
                let bounds = even_bounds(t0, n, jobs);
                let (head, tail) = a.as_mut_slice().split_at_mut(t0 * c);
                let head: &[f64] = head;
                let lbb = &head[off * c + off..];
                for_row_chunks_multi(
                    vec![(tail, c), (&mut panel[..], nb)],
                    &bounds,
                    ctx,
                    |chunks, r0, r1| {
                        let mut it = chunks.into_iter();
                        let achunk = it.next().expect("matrix chunk");
                        let pchunk = it.next().expect("panel chunk");
                        let q = r1 - r0;
                        for lr in 0..q {
                            pchunk[lr * nb..(lr + 1) * nb]
                                .copy_from_slice(&achunk[lr * c + off..lr * c + off + nb]);
                        }
                        micro::solve_lower_rows(lbb, c, nb, pchunk, nb, q);
                        for lr in 0..q {
                            achunk[lr * c + off..lr * c + off + nb]
                                .copy_from_slice(&pchunk[lr * nb..(lr + 1) * nb]);
                        }
                    },
                );
            }
            // 3. rank-nb trailing update `A −= P·Pᵀ` on the lower
            // triangle, every worker reading the shared solved panel
            {
                let bounds = weighted_bounds(t0, n, jobs, |i| (i - t0 + 1) as f64);
                let (_, tail) = a.as_mut_slice().split_at_mut(t0 * c);
                let panel_ref: &[f64] = &panel;
                for_row_chunks(tail, c, &bounds, ctx, |chunk, r0, r1| {
                    let m_rows = r1 - r0;
                    let ncols = r1 - t0;
                    micro::gemm_nt(
                        &mut chunk[t0..(m_rows - 1) * c + r1],
                        c,
                        m_rows,
                        ncols,
                        nb,
                        &panel_ref[(r0 - t0) * nb..],
                        nb,
                        panel_ref,
                        nb,
                        -1.0,
                        micro::Clip::Lower((r0 - t0) as isize),
                    );
                });
            }
        }
        off = t0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Random SPD matrix A Aᵀ + n·I.
    fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        let mut spd = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[(i, k)] * a[(j, k)];
                }
                spd[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        spd
    }

    #[test]
    fn reconstructs_small() {
        let k = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Chol::factor(&k).unwrap();
        let l = ch.factor_matrix();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-14);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-14);
        assert!((l[(1, 1)] - 2f64.sqrt()).abs() < 1e-14);
        assert!((ch.logdet() - (4.0f64 * 3.0 - 4.0).ln()).abs() < 1e-13);
    }

    #[test]
    fn reconstruction_various_sizes() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        // cover: < NB, == NB, just above NB, multiple blocks, ragged tail
        for &n in &[1usize, 2, 5, 17, 64, 65, 100, 130, 200] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let l = ch.factor_matrix();
            // ‖L Lᵀ − K‖_max relative to diagonal scale
            let scale = (0..n).map(|i| k[(i, i)]).fold(0.0, f64::max);
            let mut max_err = 0.0f64;
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0;
                    for t in 0..=j {
                        s += l[(i, t)] * l[(j, t)];
                    }
                    max_err = max_err.max((s - k[(i, j)]).abs());
                }
            }
            assert!(
                max_err / scale < 1e-12,
                "n={n}: reconstruction error {max_err:.3e} (scale {scale:.3e})"
            );
        }
    }

    #[test]
    fn parallel_factor_is_bit_identical() {
        let mut rng = Xoshiro256::seed_from_u64(41);
        // sizes straddling NB and the PAR_MIN_ROWS dispatch cutoff
        for &n in &[40usize, 64, 65, 112, 113, 160, 200, 300] {
            let k = random_spd(n, &mut rng);
            let serial = Chol::factor(&k).unwrap();
            for threads in [2usize, 3, 4] {
                let ctx = ExecutionContext::new(threads);
                let par = Chol::factor_with(&k, &ctx).unwrap();
                assert_eq!(
                    par.factor_matrix().max_abs_diff(serial.factor_matrix()),
                    0.0,
                    "n={n} threads={threads}: factor differs"
                );
                assert_eq!(par.logdet(), serial.logdet(), "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn solve_residual() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        for &n in &[3usize, 50, 120] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = ch.solve(&b);
            let r = k.matvec(&x);
            for i in 0..n {
                assert!((r[i] - b[i]).abs() < 1e-9, "n={n} residual {}", (r[i] - b[i]).abs());
            }
        }
    }

    #[test]
    fn logdet_matches_product_of_pivots() {
        // diag matrix: logdet exact
        let k = Matrix::diag(&[2.0, 3.0, 4.0]);
        let ch = Chol::factor(&k).unwrap();
        assert!((ch.logdet() - 24f64.ln()).abs() < 1e-14);
    }

    #[test]
    fn inv_quad_matches_solve() {
        let mut rng = Xoshiro256::seed_from_u64(29);
        let k = random_spd(40, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let q1 = ch.inv_quad(&b);
        let x = ch.solve(&b);
        let q2 = crate::linalg::dot(&b, &x);
        assert!((q1 - q2).abs() < 1e-9 * q1.abs());
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        let k = random_spd(30, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let inv = ch.inverse();
        let prod = k.matmul(&inv);
        let eye = Matrix::eye(30);
        assert!(prod.max_abs_diff(&eye) < 1e-9, "K K⁻¹ ≠ I: {}", prod.max_abs_diff(&eye));
    }

    /// The blocked stage-1 triangular inversion (rows of `U` through
    /// `micro::solve_lower_rows`) must agree with the scalar recurrence
    /// it replaced to ≤1e-12 relative, for sizes straddling the INV_RB
    /// group grid.
    #[test]
    fn blocked_inverse_matches_scalar_recurrence() {
        let mut rng = Xoshiro256::seed_from_u64(101);
        for &n in &[1usize, 7, 31, 32, 33, 64, 97, 150] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let got = ch.inverse();
            // reference: the pre-blocking scalar recurrence for
            // U[j][i] = (L⁻¹)[i][j], then the naive symmetric product
            let l = ch.factor_matrix();
            let mut u = Matrix::zeros(n, n);
            for j in 0..n {
                u[(j, j)] = 1.0 / l[(j, j)];
                for i in (j + 1)..n {
                    let mut acc = 0.0;
                    for t in j..i {
                        acc += l[(i, t)] * u[(j, t)];
                    }
                    u[(j, i)] = -acc / l[(i, i)];
                }
            }
            let mut want = Matrix::zeros(n, n);
            for a in 0..n {
                for b in 0..n {
                    let mut s = 0.0;
                    for t in a.max(b)..n {
                        s += u[(a, t)] * u[(b, t)];
                    }
                    want[(a, b)] = s;
                }
            }
            let scale = (0..n).map(|i| want[(i, i)].abs()).fold(1e-300, f64::max);
            let rel = got.max_abs_diff(&want) / scale;
            assert!(rel < 1e-12, "n={n}: blocked vs scalar inverse drift {rel:.3e}");
        }
    }

    #[test]
    fn parallel_inverse_and_solve_mat_match_serial() {
        let mut rng = Xoshiro256::seed_from_u64(43);
        for &n in &[60usize, 150] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let serial_inv = ch.inverse();
            let mut b = Matrix::zeros(n, 5);
            for i in 0..n {
                for j in 0..5 {
                    b[(i, j)] = rng.normal();
                }
            }
            let serial_x = ch.solve_mat(&b);
            for threads in [2usize, 4] {
                let ctx = ExecutionContext::new(threads);
                assert_eq!(ch.inverse_with(&ctx).max_abs_diff(&serial_inv), 0.0);
                assert_eq!(ch.solve_mat_with(&b, &ctx).max_abs_diff(&serial_x), 0.0);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let k = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        let err = Chol::factor(&k).unwrap_err();
        assert_eq!(err.pivot, 1);
        assert!(err.value <= 0.0);
    }

    #[test]
    fn rejects_indefinite_in_parallel() {
        // indefinite beyond the first block so the parallel path has run
        let mut rng = Xoshiro256::seed_from_u64(47);
        let mut k = random_spd(200, &mut rng);
        k[(150, 150)] = -1e6;
        let ctx = ExecutionContext::new(4);
        assert!(Chol::factor_with(&k, &ctx).is_err());
    }

    /// Max |A − B| over the lower triangles only (the upper triangle of a
    /// factor is garbage by contract).
    fn lower_diff(a: &Matrix, b: &Matrix) -> f64 {
        assert_eq!(a.rows(), b.rows());
        let mut d = 0.0f64;
        for i in 0..a.rows() {
            for j in 0..=i {
                d = d.max((a[(i, j)] - b[(i, j)]).abs());
            }
        }
        d
    }

    #[test]
    fn extend_matches_cold_factor() {
        let mut rng = Xoshiro256::seed_from_u64(53);
        for &n in &[1usize, 5, 30, 90] {
            let big = random_spd(n + 3, &mut rng);
            // factor the leading n×n, then extend three times
            let mut lead = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    lead[(i, j)] = big[(i, j)];
                }
            }
            let mut ch = Chol::factor(&lead).unwrap();
            for k in n..n + 3 {
                let cross: Vec<f64> = (0..k).map(|i| big[(k, i)]).collect();
                ch.extend(&cross, big[(k, k)]).unwrap();
            }
            let cold = Chol::factor(&big).unwrap();
            assert_eq!(ch.dim(), n + 3);
            let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
            assert!(d < 1e-10, "n={n}: extended factor differs from cold by {d:.3e}");
            assert!(
                (ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs().max(1.0),
                "n={n}: logdet {} vs {}",
                ch.logdet(),
                cold.logdet()
            );
            // the grown factor must actually solve the grown system
            let b: Vec<f64> = (0..n + 3).map(|_| rng.normal()).collect();
            let x = ch.solve(&b);
            let r = big.matvec(&x);
            for i in 0..n + 3 {
                assert!((r[i] - b[i]).abs() < 1e-8, "residual {}", (r[i] - b[i]).abs());
            }
        }
    }

    #[test]
    fn extend_rejects_non_pd_border() {
        let mut rng = Xoshiro256::seed_from_u64(59);
        let k = random_spd(20, &mut rng);
        let mut ch = Chol::factor(&k).unwrap();
        // bordering with K's own first column and half its diagonal makes
        // the Schur complement −K₀₀/2 < 0
        let cross: Vec<f64> = (0..20).map(|i| k[(i, 0)]).collect();
        let err = ch.extend(&cross, 0.5 * k[(0, 0)]).unwrap_err();
        assert_eq!(err.pivot, 20);
        assert!(err.value <= 0.0);
    }

    #[test]
    fn rank1_update_matches_cold_factor() {
        let mut rng = Xoshiro256::seed_from_u64(61);
        for &n in &[1usize, 7, 40, 120] {
            let k = random_spd(n, &mut rng);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut kx = k.clone();
            for i in 0..n {
                for j in 0..n {
                    kx[(i, j)] += x[i] * x[j];
                }
            }
            let mut ch = Chol::factor(&k).unwrap();
            let mut scratch = x.clone();
            ch.rank1_update(&mut scratch);
            let cold = Chol::factor(&kx).unwrap();
            let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
            assert!(d < 1e-10, "n={n}: updated factor differs from cold by {d:.3e}");
            assert!((ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs().max(1.0));
        }
    }

    #[test]
    fn rank1_update_downdate_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(67);
        for &n in &[5usize, 50, 150] {
            let k = random_spd(n, &mut rng);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let orig = Chol::factor(&k).unwrap();
            let mut ch = orig.clone();
            let mut up = x.clone();
            ch.rank1_update(&mut up);
            let mut down = x.clone();
            ch.rank1_downdate(&mut down).unwrap();
            let d = lower_diff(ch.factor_matrix(), orig.factor_matrix());
            assert!(d < 1e-10, "n={n}: update→downdate drifts by {d:.3e}");
            assert!((ch.logdet() - orig.logdet()).abs() < 1e-9 * orig.logdet().abs().max(1.0));
        }
    }

    #[test]
    fn rank1_downdate_rejects_non_pd() {
        let k = Matrix::diag(&[4.0, 9.0]);
        let mut ch = Chol::factor(&k).unwrap();
        // subtracting xxᵀ with x = (3, 0) makes the (0,0) pivot negative
        let mut x = vec![3.0, 0.0];
        let err = ch.rank1_downdate(&mut x).unwrap_err();
        assert_eq!(err.pivot, 0);
        assert!(err.value <= 0.0);
    }

    /// Regression for the recoverable-downdate guard: a failed downdate
    /// must leave the factor bitwise untouched, including the
    /// near-singular case where every pivot stays positive but the
    /// hyperbolic rotation overflows the column (`1/cos → ∞`) — the old
    /// in-place sweep would commit `inf` entries and NaN-poison every
    /// later solve.
    #[test]
    fn rank1_downdate_failure_leaves_factor_untouched() {
        // near-singular trailing block: pivot d = 1 − (1−2⁻⁵³)² ≈ 2.2e−16
        // stays positive, but the huge subdiagonal entry divided by
        // co ≈ 1.5e−8 overflows to inf
        let l = Matrix::from_rows(&[&[1.0, 0.0], &[1e305, 1.0]]);
        let logdet0 = 0.0; // 2·(ln 1 + ln 1)
        let mut ch = Chol::from_parts(l.clone(), logdet0);
        let mut x = vec![1.0 - f64::EPSILON / 2.0, 0.0];
        let err = ch.rank1_downdate(&mut x).unwrap_err();
        assert_eq!(err.pivot, 0);
        assert!(err.value > 0.0, "near-singular pivot is positive: {}", err.value);
        assert_eq!(
            ch.factor_matrix().max_abs_diff(&l),
            0.0,
            "failed downdate must not mutate the factor"
        );
        assert_eq!(ch.logdet(), logdet0);

        // indefinite case: also untouched (was: partially swept)
        let k = random_spd(40, &mut Xoshiro256::seed_from_u64(97));
        let orig = Chol::factor(&k).unwrap();
        let mut ch = orig.clone();
        // x = 10·(first column of L) makes the first pivot negative —
        // caught at k = 0 after no scratch commit
        let mut x: Vec<f64> = (0..40).map(|i| 10.0 * orig.factor_matrix()[(i, 0)]).collect();
        assert!(ch.rank1_downdate(&mut x).is_err());
        assert_eq!(ch.factor_matrix().max_abs_diff(orig.factor_matrix()), 0.0);
        assert_eq!(ch.logdet(), orig.logdet());
    }

    #[test]
    fn remove_row_matches_cold_factor_of_reduced_matrix() {
        let mut rng = Xoshiro256::seed_from_u64(73);
        for &n in &[2usize, 5, 30, 90] {
            for &i in &[0usize, 1, n / 2, n - 1] {
                let k = random_spd(n, &mut rng);
                let mut ch = Chol::factor(&k).unwrap();
                ch.remove_row(i);
                // cold factor of K with row/column i struck out
                let mut red = Matrix::zeros(n - 1, n - 1);
                for r in 0..n - 1 {
                    for c in 0..n - 1 {
                        let (ro, co) = (r + (r >= i) as usize, c + (c >= i) as usize);
                        red[(r, c)] = k[(ro, co)];
                    }
                }
                let cold = Chol::factor(&red).unwrap();
                let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
                assert!(d < 1e-10, "n={n} i={i}: removed factor differs from cold by {d:.3e}");
                assert!(
                    (ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs().max(1.0),
                    "n={n} i={i}: logdet {} vs {}",
                    ch.logdet(),
                    cold.logdet()
                );
                // the reduced factor actually solves the reduced system
                let b: Vec<f64> = (0..n - 1).map(|_| rng.normal()).collect();
                let x = ch.solve(&b);
                let r = red.matvec(&x);
                for j in 0..n - 1 {
                    assert!((r[j] - b[j]).abs() < 1e-8, "residual {}", (r[j] - b[j]).abs());
                }
            }
        }
    }

    #[test]
    fn shrink_front_matches_cold_factor_of_trailing_block() {
        let mut rng = Xoshiro256::seed_from_u64(79);
        for &(n, k) in &[(3usize, 1usize), (10, 3), (60, 20), (90, 89)] {
            let big = random_spd(n, &mut rng);
            let mut ch = Chol::factor(&big).unwrap();
            ch.shrink_front(k);
            assert_eq!(ch.dim(), n - k);
            let m = n - k;
            let mut tail = Matrix::zeros(m, m);
            for r in 0..m {
                for c in 0..m {
                    tail[(r, c)] = big[(r + k, c + k)];
                }
            }
            let cold = Chol::factor(&tail).unwrap();
            let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
            assert!(d < 1e-10, "n={n} k={k}: shrunk factor differs from cold by {d:.3e}");
            assert!(
                (ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs().max(1.0)
            );
        }
        // shrink_front(0) is a no-op; shrink_front(n) empties the factor
        let k2 = random_spd(8, &mut rng);
        let mut ch = Chol::factor(&k2).unwrap();
        let before = ch.factor_matrix().clone();
        ch.shrink_front(0);
        assert_eq!(ch.factor_matrix().max_abs_diff(&before), 0.0);
        ch.shrink_front(8);
        assert_eq!(ch.dim(), 0);
    }

    /// Deleting the just-appended trailing row restores the original
    /// factor (extend ∘ evict round trip at the `Chol` level).
    #[test]
    fn extend_then_remove_last_row_round_trips() {
        let mut rng = Xoshiro256::seed_from_u64(83);
        let big = random_spd(41, &mut rng);
        let mut lead = Matrix::zeros(40, 40);
        for i in 0..40 {
            for j in 0..40 {
                lead[(i, j)] = big[(i, j)];
            }
        }
        let orig = Chol::factor(&lead).unwrap();
        let mut ch = orig.clone();
        let cross: Vec<f64> = (0..40).map(|i| big[(40, i)]).collect();
        ch.extend(&cross, big[(40, 40)]).unwrap();
        ch.remove_row(40);
        let d = lower_diff(ch.factor_matrix(), orig.factor_matrix());
        assert!(d < 1e-12, "extend→remove_row drifted by {d:.3e}");
        assert!((ch.logdet() - orig.logdet()).abs() < 1e-10 * orig.logdet().abs().max(1.0));
    }

    /// The blocked multi-row TRSM reorders the per-entry sums relative to
    /// the scalar [`solve_lower`] (the micro-kernel order is the
    /// canonical one), so this is a rounding-level comparison — but it
    /// must be bit-identical across thread counts and batch splits.
    #[test]
    fn half_solve_rows_matches_scalar_half_solve_to_rounding() {
        let mut rng = Xoshiro256::seed_from_u64(71);
        for &n in &[30usize, 300] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let q = 7;
            let mut b = Matrix::zeros(q, n);
            for r in 0..q {
                for j in 0..n {
                    b[(r, j)] = rng.normal();
                }
            }
            let want: Vec<Vec<f64>> = (0..q).map(|r| ch.half_solve(b.row(r))).collect();
            let serial = {
                let mut got = b.clone();
                ch.half_solve_rows_with(&mut got, &ExecutionContext::seq());
                got
            };
            for r in 0..q {
                for j in 0..n {
                    let w = want[r][j];
                    assert!(
                        (serial[(r, j)] - w).abs() < 1e-11 * w.abs().max(1.0),
                        "n={n} row={r} col={j}: {} vs scalar {w}",
                        serial[(r, j)]
                    );
                }
            }
            // single-row batches must reproduce the q-row batch bitwise
            for r in 0..q {
                let mut one = Matrix::zeros(1, n);
                one.row_mut(0).copy_from_slice(b.row(r));
                ch.half_solve_rows_with(&mut one, &ExecutionContext::seq());
                assert_eq!(one.row(0), serial.row(r), "n={n} row={r} batch-split drift");
            }
            for threads in [2usize, 3] {
                let ctx = ExecutionContext::new(threads);
                let mut got = b.clone();
                ch.half_solve_rows_with(&mut got, &ctx);
                assert_eq!(got.max_abs_diff(&serial), 0.0, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn solve_mat_multi_rhs() {
        let mut rng = Xoshiro256::seed_from_u64(37);
        let k = random_spd(25, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let mut b = Matrix::zeros(25, 3);
        for i in 0..25 {
            for j in 0..3 {
                b[(i, j)] = rng.normal();
            }
        }
        let x = ch.solve_mat(&b);
        let r = k.matmul(&x);
        assert!(r.max_abs_diff(&b) < 1e-9);
    }
}
