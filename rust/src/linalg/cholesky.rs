//! Blocked Cholesky factorisation — the `O(n³)` hot path of the paper.
//!
//! `K = L Lᵀ` with `L` lower triangular. The factorisation is
//! *right-looking* and blocked: for each diagonal block we factor a small
//! `nb×nb` panel unblocked, triangular-solve the panel below it, and then
//! apply a symmetric rank-`nb` update to the trailing submatrix. The
//! trailing update is where ~all the FLOPs are; it is written as a
//! register-blocked `C -= A Bᵀ` micro-kernel over row-major storage that
//! the compiler auto-vectorises.

use super::{solve_lower, solve_lower_transpose, Matrix};
use std::fmt;

/// Block size for the panel factorisation. 48–96 all perform similarly on
/// the benchmark machine; 64 keeps the panel (64·n doubles) in L2.
const NB: usize = 64;

/// Error: matrix was not positive definite.
#[derive(Debug, Clone, Copy)]
pub struct CholError {
    /// Index of the pivot that failed.
    pub pivot: usize,
    /// Value of the failed pivot.
    pub value: f64,
}

impl fmt::Display for CholError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "matrix not positive definite: pivot {} = {:.3e} <= 0",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholError {}

/// A computed Cholesky factorisation with the operations the GP layer
/// needs: solves, log-determinant, quadratic forms.
#[derive(Debug, Clone)]
pub struct Chol {
    /// Lower-triangular factor (upper triangle is garbage, never read).
    l: Matrix,
    logdet: f64,
}

impl Chol {
    /// Factor a symmetric positive-definite matrix.
    ///
    /// Only the lower triangle of `k` is read.
    pub fn factor(k: &Matrix) -> Result<Self, CholError> {
        let mut l = k.clone();
        factor_in_place(&mut l)?;
        let n = l.rows();
        let mut logdet = 0.0;
        for i in 0..n {
            logdet += l[(i, i)].ln();
        }
        Ok(Self { l, logdet: 2.0 * logdet })
    }

    /// Factor, consuming the input matrix (no copy) — used on the hot path
    /// where the covariance buffer is rebuilt every iteration anyway.
    pub fn factor_owned(mut k: Matrix) -> Result<Self, CholError> {
        factor_in_place(&mut k)?;
        let n = k.rows();
        let mut logdet = 0.0;
        for i in 0..n {
            logdet += k[(i, i)].ln();
        }
        Ok(Self { l: k, logdet: 2.0 * logdet })
    }

    /// Dimension `n`.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// `ln det K = 2 Σ ln L_ii` — the determinant term of eq. (2.5).
    pub fn logdet(&self) -> f64 {
        self.logdet
    }

    /// The lower-triangular factor.
    pub fn factor_matrix(&self) -> &Matrix {
        &self.l
    }

    /// Solve `K x = b` (two triangular solves).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        solve_lower(&self.l, &mut x);
        solve_lower_transpose(&self.l, &mut x);
        x
    }

    /// Solve `L w = b` only (half-solve; `wᵀw = bᵀ K⁻¹ b`).
    pub fn half_solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        solve_lower(&self.l, &mut x);
        x
    }

    /// Quadratic form `bᵀ K⁻¹ b` via one triangular solve.
    pub fn inv_quad(&self, b: &[f64]) -> f64 {
        let w = self.half_solve(b);
        super::dot(&w, &w)
    }

    /// Solve `K X = B` for a multi-column right-hand side, column-blocked.
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows(), self.dim());
        let n = self.dim();
        let m = b.cols();
        // Work column-major for solve locality: transpose, solve rows, undo.
        let bt = b.transpose();
        let mut out = Matrix::zeros(m, n);
        for c in 0..m {
            let mut x = bt.row(c).to_vec();
            solve_lower(&self.l, &mut x);
            solve_lower_transpose(&self.l, &mut x);
            out.row_mut(c).copy_from_slice(&x);
        }
        out.transpose()
    }

    /// Explicit inverse `K⁻¹ = L⁻ᵀ L⁻¹` (dpotri-style).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): this used to solve `K X = I`
    /// column by column (≈ 2n³ flops, column-strided access). It now does
    /// a triangular inversion into `U = (L⁻¹)ᵀ` — whose recurrence walks
    /// both operands along contiguous rows — followed by the symmetric
    /// product `W_ab = Σ_k U_ak U_bk`, for ≈ n³/2 flops total with
    /// sequential access. ~5× faster at n ≈ 2000.
    pub fn inverse(&self) -> Matrix {
        let n = self.dim();
        let c = self.l.cols();
        let ld = self.l.as_slice();
        // U[j][i] = (L⁻¹)[i][j] for i ≥ j (row-major upper triangle):
        //   U[j][j] = 1/L[j][j]
        //   U[j][i] = −(Σ_{k=j}^{i−1} L[i][k] U[j][k]) / L[i][i]
        let mut u = Matrix::zeros(n, n);
        for j in 0..n {
            let urow = u.row_mut(j);
            urow[j] = 1.0 / ld[j * c + j];
            for i in (j + 1)..n {
                let lrow = &ld[i * c..i * c + i];
                let mut acc = 0.0;
                for k in j..i {
                    acc += lrow[k] * urow[k];
                }
                urow[i] = -acc / ld[i * c + i];
            }
        }
        // W[a][b] = Σ_{k ≥ max(a,b)} U[a][k] U[b][k]
        let mut w = Matrix::zeros(n, n);
        for a in 0..n {
            for b in a..n {
                let ua = u.row(a);
                let ub = u.row(b);
                let mut acc = 0.0;
                for k in b..n {
                    acc += ua[k] * ub[k];
                }
                w[(a, b)] = acc;
                w[(b, a)] = acc;
            }
        }
        w
    }
}

/// Unblocked lower Cholesky on the leading `n×n` of `a` (for panels).
fn factor_unblocked(a: &mut Matrix, off: usize, n: usize) -> Result<(), CholError> {
    for j in off..off + n {
        // diagonal
        let mut d = a[(j, j)];
        for k in off..j {
            let v = a[(j, k)];
            d -= v * v;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError { pivot: j, value: d });
        }
        let d = d.sqrt();
        a[(j, j)] = d;
        let inv_d = 1.0 / d;
        // column below the diagonal
        for i in (j + 1)..off + n {
            let mut s = a[(i, j)];
            let (ri, rj) = (i, j);
            // s -= Σ_k a[i,k] a[j,k]
            let arow_i = ri * a.cols();
            let arow_j = rj * a.cols();
            let data = a.as_slice();
            let mut acc = 0.0;
            for k in off..j {
                acc += data[arow_i + k] * data[arow_j + k];
            }
            s -= acc;
            a[(i, j)] = s * inv_d;
        }
    }
    Ok(())
}

/// Triangular solve of the panel: rows `r0..r1`, solving against the
/// already-factored diagonal block at `[off..off+nb, off..off+nb]`:
/// `A[r, off..off+nb] ← A[r, off..off+nb] · L_bb⁻ᵀ`.
fn panel_trsm(a: &mut Matrix, off: usize, nb: usize, r0: usize, r1: usize) {
    let c = a.cols();
    for r in r0..r1 {
        for j in off..off + nb {
            // x_j = (a[r,j] - Σ_{k<j} x_k L[j,k]) / L[j,j]
            let mut s = a.as_slice()[r * c + j];
            let lrow = j * c;
            let data = a.as_slice();
            let mut acc = 0.0;
            for k in off..j {
                acc += data[r * c + k] * data[lrow + k];
            }
            s -= acc;
            let v = s / a.as_slice()[lrow + j];
            a.as_mut_slice()[r * c + j] = v;
        }
    }
}

/// Trailing symmetric rank-`nb` update:
/// `A[i, j] -= Σ_k A[i, off+k] · A[j, off+k]` for `t0 ≤ j ≤ i < n`,
/// lower triangle only. This is the FLOP-dominant kernel; written with a
/// 2×-row outer unroll over contiguous row-major panels so LLVM emits
/// fused vector FMAs.
fn trailing_syrk(a: &mut Matrix, off: usize, nb: usize, t0: usize, n: usize) {
    let c = a.cols();
    let data = a.as_mut_slice();
    let mut i = t0;
    while i < n {
        let pair = i + 1 < n;
        // panel rows (the already-solved columns off..off+nb)
        let (pi0, pi1) = (i * c + off, (i + 1) * c + off);
        for j in t0..=i {
            let pj = j * c + off;
            let mut acc0 = 0.0;
            let mut acc1 = 0.0;
            for k in 0..nb {
                let bjk = data[pj + k];
                acc0 += data[pi0 + k] * bjk;
                if pair {
                    acc1 += data[pi1 + k] * bjk;
                }
            }
            data[i * c + j] -= acc0;
            if pair && j <= i + 1 {
                data[(i + 1) * c + j] -= acc1;
            }
        }
        if pair {
            // finish the (i+1, i+1) entry not covered by j ≤ i
            let j = i + 1;
            let pj = j * c + off;
            let mut acc = 0.0;
            for k in 0..nb {
                let v = data[pj + k];
                acc += v * v;
            }
            data[j * c + j] -= acc;
        }
        i += 2;
    }
}

/// In-place blocked lower Cholesky. Only the lower triangle is referenced.
pub(crate) fn factor_in_place(a: &mut Matrix) -> Result<(), CholError> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "Cholesky requires a square matrix");
    let mut off = 0;
    while off < n {
        let nb = NB.min(n - off);
        // 1. factor the diagonal panel
        factor_unblocked(a, off, nb)?;
        let t0 = off + nb;
        if t0 < n {
            // 2. solve the sub-diagonal panel against the diagonal block
            panel_trsm(a, off, nb, t0, n);
            // 3. rank-nb update of the trailing lower triangle
            trailing_syrk(a, off, nb, t0, n);
        }
        off = t0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Random SPD matrix A Aᵀ + n·I.
    fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        let mut spd = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[(i, k)] * a[(j, k)];
                }
                spd[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        spd
    }

    #[test]
    fn reconstructs_small() {
        let k = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Chol::factor(&k).unwrap();
        let l = ch.factor_matrix();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-14);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-14);
        assert!((l[(1, 1)] - 2f64.sqrt()).abs() < 1e-14);
        assert!((ch.logdet() - (4.0f64 * 3.0 - 4.0).ln()).abs() < 1e-13);
    }

    #[test]
    fn reconstruction_various_sizes() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        // cover: < NB, == NB, just above NB, multiple blocks, ragged tail
        for &n in &[1usize, 2, 5, 17, 64, 65, 100, 130, 200] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let l = ch.factor_matrix();
            // ‖L Lᵀ − K‖_max relative to diagonal scale
            let scale = (0..n).map(|i| k[(i, i)]).fold(0.0, f64::max);
            let mut max_err = 0.0f64;
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0;
                    for t in 0..=j {
                        s += l[(i, t)] * l[(j, t)];
                    }
                    max_err = max_err.max((s - k[(i, j)]).abs());
                }
            }
            assert!(
                max_err / scale < 1e-12,
                "n={n}: reconstruction error {max_err:.3e} (scale {scale:.3e})"
            );
        }
    }

    #[test]
    fn solve_residual() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        for &n in &[3usize, 50, 120] {
            let k = random_spd(n, &mut rng);
            let ch = Chol::factor(&k).unwrap();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = ch.solve(&b);
            let r = k.matvec(&x);
            for i in 0..n {
                assert!((r[i] - b[i]).abs() < 1e-9, "n={n} residual {}", (r[i] - b[i]).abs());
            }
        }
    }

    #[test]
    fn logdet_matches_product_of_pivots() {
        // diag matrix: logdet exact
        let k = Matrix::diag(&[2.0, 3.0, 4.0]);
        let ch = Chol::factor(&k).unwrap();
        assert!((ch.logdet() - 24f64.ln()).abs() < 1e-14);
    }

    #[test]
    fn inv_quad_matches_solve() {
        let mut rng = Xoshiro256::seed_from_u64(29);
        let k = random_spd(40, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let q1 = ch.inv_quad(&b);
        let x = ch.solve(&b);
        let q2 = crate::linalg::dot(&b, &x);
        assert!((q1 - q2).abs() < 1e-9 * q1.abs());
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        let k = random_spd(30, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let inv = ch.inverse();
        let prod = k.matmul(&inv);
        let eye = Matrix::eye(30);
        assert!(prod.max_abs_diff(&eye) < 1e-9, "K K⁻¹ ≠ I: {}", prod.max_abs_diff(&eye));
    }

    #[test]
    fn rejects_indefinite() {
        let k = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        let err = Chol::factor(&k).unwrap_err();
        assert_eq!(err.pivot, 1);
        assert!(err.value <= 0.0);
    }

    #[test]
    fn solve_mat_multi_rhs() {
        let mut rng = Xoshiro256::seed_from_u64(37);
        let k = random_spd(25, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let mut b = Matrix::zeros(25, 3);
        for i in 0..25 {
            for j in 0..3 {
                b[(i, j)] = rng.normal();
            }
        }
        let x = ch.solve_mat(&b);
        let r = k.matmul(&x);
        assert!(r.max_abs_diff(&b) < 1e-9);
    }
}
