//! Diagonally pivoted LDLᵀ factorisation — the indefinite-safe fallback.
//!
//! `P A Pᵀ = L D Lᵀ` with `L` unit lower triangular, `D` diagonal and `P`
//! a symmetric row/column permutation chosen greedily by largest remaining
//! diagonal magnitude. Unlike the Cholesky of [`super::Chol`], the
//! factorisation is *total*: it never fails, even on indefinite or
//! singular input — pivots whose magnitude falls below a relative
//! tolerance are classified as numerically zero and their elimination
//! step is skipped. That makes it the right tool for the bottom rung of
//! the jitter-escalation ladder ([`crate::gp::profiled`]): when every
//! jittered LLᵀ attempt has failed, the LDLᵀ inertia and minimum pivot
//! diagnose *how* indefinite `K̃` is and calibrate the final repair.
//!
//! Diagonal (1×1) pivoting is not as robust as Bunch–Kaufman 2×2
//! pivoting on adversarial indefinite matrices (a zero diagonal with
//! large off-diagonal coupling loses accuracy), but the matrices arriving
//! here are symmetric covariances that are PD up to rounding — near-zero
//! or slightly negative eigenvalues — where diagonal pivoting is accurate
//! and half the code. The trailing update runs on full symmetric storage
//! (simpler pivot swaps), so the factorisation costs ~2× the flops of the
//! packed LLᵀ; it only runs on the rare escalation path.

use super::{axpy, Matrix};

/// Signature of a symmetric matrix: the count of positive, negative and
/// (numerically) zero eigenvalues, read off the LDLᵀ pivots by
/// Sylvester's law of inertia.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Inertia {
    pub positive: usize,
    pub negative: usize,
    pub zero: usize,
}

/// A computed `P A Pᵀ = L D Lᵀ` factorisation.
#[derive(Clone, Debug)]
pub struct Ldlt {
    /// Unit lower triangle (strict lower part stored; diagonal implicit).
    l: Matrix,
    /// The diagonal of `D`; entries classified as numerically zero are
    /// stored as exact `0.0`.
    d: Vec<f64>,
    /// `perm[i]` = original row/column sitting at pivoted position `i`.
    perm: Vec<usize>,
    /// Relative zero-pivot threshold used during factorisation.
    tol: f64,
}

impl Ldlt {
    /// Factor a symmetric matrix. Reads the full matrix (both triangles;
    /// it is symmetrised on entry like [`super::sym_eigen`]). Never
    /// fails: rank deficiency shows up as zero entries of `d`.
    pub fn factor(a: &Matrix) -> Self {
        assert_eq!(a.rows(), a.cols(), "LDLᵀ requires a square matrix");
        let n = a.rows();
        let mut m = a.clone();
        m.symmetrize();
        let max_diag = (0..n).map(|i| m[(i, i)].abs()).fold(0.0f64, f64::max);
        // Relative zero threshold: anything the elimination drives below
        // n·ε·max|a_ii| is indistinguishable from zero at working
        // precision.
        let tol = (n as f64) * f64::EPSILON * max_diag.max(f64::MIN_POSITIVE);
        let mut l = Matrix::zeros(n, n);
        let mut d = vec![0.0; n];
        let mut perm: Vec<usize> = (0..n).collect();
        let mut col = vec![0.0; n];
        for k in 0..n {
            // greedy diagonal pivot: largest remaining |m_ii|
            let mut p = k;
            let mut best = m[(k, k)].abs();
            for i in (k + 1)..n {
                let v = m[(i, i)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if p != k {
                swap_sym(&mut m, k, p);
                swap_rows_prefix(&mut l, k, p, k);
                perm.swap(k, p);
            }
            let dk = m[(k, k)];
            if !(dk.abs() > tol) {
                // numerically zero pivot (or NaN): skip elimination. The
                // remaining diagonal is ≤ tol too (pivoting picked the
                // max), so the whole trailing block is noise.
                d[k] = 0.0;
                continue;
            }
            d[k] = dk;
            let inv = 1.0 / dk;
            for i in (k + 1)..n {
                col[i] = m[(i, k)] * inv;
                l[(i, k)] = col[i];
            }
            // trailing update on full symmetric storage:
            // m[i][j] -= l_i · d · l_j
            for i in (k + 1)..n {
                let scale = -col[i] * dk;
                let (lcol, row) = (&col[(k + 1)..n], &mut m.row_mut(i)[(k + 1)..n]);
                axpy(scale, lcol, row);
            }
        }
        Self { l, d, perm, tol }
    }

    /// Dimension `n`.
    pub fn dim(&self) -> usize {
        self.d.len()
    }

    /// The pivot diagonal `D` (in pivoted order; zeros mark numerically
    /// singular directions).
    pub fn d(&self) -> &[f64] {
        &self.d
    }

    /// The smallest (most negative) pivot — a cheap proxy for how far the
    /// matrix is from positive definite. `0.0` for an exactly
    /// rank-deficient PSD matrix.
    pub fn min_d(&self) -> f64 {
        self.d.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Matrix inertia via Sylvester's law: the signs of `D` are the signs
    /// of the eigenvalues.
    pub fn inertia(&self) -> Inertia {
        let mut it = Inertia { positive: 0, negative: 0, zero: 0 };
        for &v in &self.d {
            if v > self.tol {
                it.positive += 1;
            } else if v < -self.tol {
                it.negative += 1;
            } else {
                it.zero += 1;
            }
        }
        it
    }

    /// `ln |det A| = Σ ln |d_i|` over the non-zero pivots. Returns
    /// `f64::NEG_INFINITY` when any pivot is numerically zero (the
    /// determinant is zero at working precision).
    pub fn logdet_abs(&self) -> f64 {
        let mut s = 0.0;
        for &v in &self.d {
            if v == 0.0 {
                return f64::NEG_INFINITY;
            }
            s += v.abs().ln();
        }
        s
    }

    /// Solve `A x = b`. Errors when the matrix is numerically singular
    /// (a zero pivot was recorded during factorisation).
    pub fn solve(&self, b: &[f64]) -> crate::Result<Vec<f64>> {
        let n = self.dim();
        assert_eq!(b.len(), n, "rhs length mismatch");
        anyhow::ensure!(
            self.d.iter().all(|&v| v != 0.0),
            "LDLᵀ solve: matrix is singular to working precision ({} zero pivot(s))",
            self.d.iter().filter(|&&v| v == 0.0).count()
        );
        // y = P b
        let mut y: Vec<f64> = self.perm.iter().map(|&i| b[i]).collect();
        // L z = y (unit lower)
        for i in 0..n {
            let s = super::dot(&self.l.row(i)[..i], &y[..i]);
            y[i] -= s;
        }
        // scale by D⁻¹
        for i in 0..n {
            y[i] /= self.d[i];
        }
        // Lᵀ v = y (unit upper via columns of L)
        for i in (0..n).rev() {
            let mut s = 0.0;
            for k in (i + 1)..n {
                s += self.l[(k, i)] * y[k];
            }
            y[i] -= s;
        }
        // x = Pᵀ v
        let mut x = vec![0.0; n];
        for (pos, &orig) in self.perm.iter().enumerate() {
            x[orig] = y[pos];
        }
        Ok(x)
    }
}

/// Symmetric swap of rows/columns `i`↔`j` of a fully-stored symmetric
/// matrix.
fn swap_sym(m: &mut Matrix, i: usize, j: usize) {
    let n = m.rows();
    if i == j {
        return;
    }
    let (ri, rj) = m.rows_mut2(i, j);
    ri.swap_with_slice(rj);
    for r in 0..n {
        let row = m.row_mut(r);
        row.swap(i, j);
    }
}

/// Swap the first `len` entries of rows `i` and `j` (the already-computed
/// part of `L` must follow the pivot permutation).
fn swap_rows_prefix(l: &mut Matrix, i: usize, j: usize, len: usize) {
    if i == j || len == 0 {
        return;
    }
    let (ri, rj) = l.rows_mut2(i, j);
    ri[..len].swap_with_slice(&mut rj[..len]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{sym_eigen, Chol};
    use crate::rng::Xoshiro256;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g[(i, j)] = rng.normal();
            }
        }
        let mut a = g.matmul(&g.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn matches_cholesky_on_pd() {
        for (n, seed) in [(5usize, 1u64), (16, 2), (33, 3), (64, 4)] {
            let a = random_spd(n, seed);
            let chol = Chol::factor(&a).unwrap();
            let ldlt = Ldlt::factor(&a);
            assert_eq!(
                ldlt.inertia(),
                Inertia { positive: n, negative: 0, zero: 0 },
                "n={n}"
            );
            assert!(
                (ldlt.logdet_abs() - chol.logdet()).abs()
                    <= 1e-10 * chol.logdet().abs().max(1.0),
                "n={n}: logdet {} vs {}",
                ldlt.logdet_abs(),
                chol.logdet()
            );
            let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let x1 = chol.solve(&b);
            let x2 = ldlt.solve(&b).unwrap();
            let scale = x1.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..n {
                assert!(
                    (x1[i] - x2[i]).abs() <= 1e-10 * scale,
                    "n={n} i={i}: {} vs {}",
                    x1[i],
                    x2[i]
                );
            }
        }
    }

    #[test]
    fn inertia_on_constructed_indefinite() {
        // rotate a known signature through a Jacobi-produced orthogonal
        // basis: A = V diag(λ) Vᵀ, λ = {+,+,−,−,−}
        let lambda = [4.0, 1.5, -0.5, -2.0, -7.0];
        let mut rng = Xoshiro256::seed_from_u64(99);
        let mut s = Matrix::zeros(5, 5);
        for i in 0..5 {
            for j in 0..=i {
                let v = rng.normal();
                s[(i, j)] = v;
                s[(j, i)] = v;
            }
        }
        let (_, v) = sym_eigen(&s); // orthogonal columns
        let mut a = Matrix::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                let mut acc = 0.0;
                for k in 0..5 {
                    acc += v[(i, k)] * lambda[k] * v[(j, k)];
                }
                a[(i, j)] = acc;
            }
        }
        let ldlt = Ldlt::factor(&a);
        assert_eq!(ldlt.inertia(), Inertia { positive: 2, negative: 3, zero: 0 });
        // solve still works on the indefinite nonsingular matrix
        let b = [1.0, -2.0, 0.5, 3.0, -1.0];
        let x = ldlt.solve(&b).unwrap();
        let r = a.matvec(&x);
        for i in 0..5 {
            assert!((r[i] - b[i]).abs() < 1e-9, "residual {i}: {} vs {}", r[i], b[i]);
        }
        // |det| = Π|λ|
        let want: f64 = lambda.iter().map(|v| v.abs().ln()).sum();
        assert!((ldlt.logdet_abs() - want).abs() < 1e-9);
    }

    #[test]
    fn rank_deficient_reports_zero_pivots() {
        // rank-2 Gram of 2 vectors in R⁴
        let u = [1.0, 2.0, -1.0, 0.5];
        let w = [0.0, 1.0, 1.0, -2.0];
        let mut a = Matrix::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                a[(i, j)] = u[i] * u[j] + w[i] * w[j];
            }
        }
        let ldlt = Ldlt::factor(&a);
        let inertia = ldlt.inertia();
        assert_eq!(inertia.positive, 2);
        assert_eq!(inertia.zero, 2);
        assert_eq!(inertia.negative, 0);
        assert_eq!(ldlt.logdet_abs(), f64::NEG_INFINITY);
        assert!(ldlt.solve(&[1.0; 4]).is_err());
    }

    #[test]
    fn min_d_flags_indefiniteness() {
        let a = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, -3.0]]);
        let ldlt = Ldlt::factor(&a);
        assert!((ldlt.min_d() + 3.0).abs() < 1e-12);
        let b = random_spd(6, 7);
        assert!(Ldlt::factor(&b).min_d() > 0.0);
    }
}
