//! Dense linear algebra substrate.
//!
//! The paper's costs are dominated by the `O(n³)` Cholesky factorisation of
//! the covariance matrix (§2); everything else — gradients, Hessians,
//! predictive variances — is `O(n²)` contractions once the factor exists.
//! This module owns that hot path in pure rust (no BLAS/LAPACK is available
//! in the build image): a blocked right-looking Cholesky, triangular
//! solves, a Levinson–Durbin Toeplitz solver (the §3(b) footnote-7
//! ablation), a small LU for Hessian determinants, and a Jacobi symmetric
//! eigensolver for bounding ellipsoids in the nested sampler.
//!
//! The `O(n³)` kernels (`Chol::factor_with`, `Chol::inverse_with`,
//! `Chol::solve_mat_with`, `Matrix::matmul_with`) accept an
//! [`ExecutionContext`] and partition their work over row tiles; the
//! plain-named entry points are the serial (`seq`) specialisations.
//! Parallel results are bit-identical to serial ones — see
//! `rust/tests/parallel_equivalence.rs`.
//!
//! All of them bottom out in the [`micro`] module: packed, register-tiled
//! GEMM/SYRK/TRSM micro-kernels whose accumulation order is the crate's
//! canonical one (fixed by the `KC`/`TB` block grids alone, so it is
//! invariant under thread count and row partition — see the [`micro`]
//! module docs for the contract).

mod matrix;
mod cholesky;
pub mod micro;
mod triangular;
mod toeplitz;
mod lu;
mod eigen;
mod ldlt;
mod spectral;

pub use matrix::Matrix;
pub use cholesky::{Chol, CholError};
/// Re-exported here because the dense kernels take it as a parameter.
pub use crate::runtime::ExecutionContext;
pub use triangular::{solve_lower, solve_lower_transpose, solve_upper};
pub use toeplitz::ToeplitzSolver;
pub use lu::Lu;
pub use eigen::{
    sym_eigen, sym_eigen_checked, sym_eigenvalues, sym_eigenvalues_with, sym_one_norm_est,
};
pub use ldlt::{Inertia, Ldlt};
pub use spectral::{spectral_reconstruct, spectral_truncate, SpectralTrunc};

/// Dot product of two equal-length slices.
///
/// Four independent `mul_add` chains reduced as `(s₀+s₁)+(s₂+s₃)` plus an
/// in-order tail — the scalar sibling of the [`micro`] kernels' FMA
/// accumulators. Deterministic for a fixed build; differs from a plain
/// sequential sum by rounding only.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let mut acc = [0.0f64; 4];
    for (x, y) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        acc[0] = x[0].mul_add(y[0], acc[0]);
        acc[1] = x[1].mul_add(y[1], acc[1]);
        acc[2] = x[2].mul_add(y[2], acc[2]);
        acc[3] = x[3].mul_add(y[3], acc[3]);
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in n4..a.len() {
        s = a[i].mul_add(b[i], s);
    }
    s
}

/// `y += alpha * x` (fused multiply-add per element).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = alpha.mul_add(xi, *yi);
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
