//! Dense linear algebra substrate.
//!
//! The paper's costs are dominated by the `O(n³)` Cholesky factorisation of
//! the covariance matrix (§2); everything else — gradients, Hessians,
//! predictive variances — is `O(n²)` contractions once the factor exists.
//! This module owns that hot path in pure rust (no BLAS/LAPACK is available
//! in the build image): a blocked right-looking Cholesky, triangular
//! solves, a Levinson–Durbin Toeplitz solver (the §3(b) footnote-7
//! ablation), a small LU for Hessian determinants, and a Jacobi symmetric
//! eigensolver for bounding ellipsoids in the nested sampler.
//!
//! The `O(n³)` kernels (`Chol::factor_with`, `Chol::inverse_with`,
//! `Chol::solve_mat_with`, `Matrix::matmul_with`) accept an
//! [`ExecutionContext`] and partition their work over row tiles; the
//! plain-named entry points are the serial (`seq`) specialisations.
//! Parallel results are bit-identical to serial ones — see
//! `rust/tests/parallel_equivalence.rs`.

mod matrix;
mod cholesky;
mod triangular;
mod toeplitz;
mod lu;
mod eigen;

pub use matrix::Matrix;
pub use cholesky::{Chol, CholError};
/// Re-exported here because the dense kernels take it as a parameter.
pub use crate::runtime::ExecutionContext;
pub use triangular::{solve_lower, solve_lower_transpose, solve_upper};
pub use toeplitz::ToeplitzSolver;
pub use lu::Lu;
pub use eigen::sym_eigen;

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
