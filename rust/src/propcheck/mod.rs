//! Mini property-based testing framework.
//!
//! `proptest` is not available in the offline build image, so the crate
//! ships its own: seeded random case generation with bisection shrinking
//! on failure. It is used by the linalg, kernel, gp and coordinator test
//! suites to state *invariants* (e.g. "Cholesky reconstructs", "assembled
//! covariance is PSD", "every scheduled job runs exactly once") rather
//! than example-based assertions only.
//!
//! ```
//! use gpfast::propcheck::{property, Gen};
//! property("reverse twice is identity", 100, |g| {
//!     let xs = g.vec_f64(0..20, -10.0, 10.0);
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     if twice == xs { Ok(()) } else { Err("mismatch".to_string()) }
//! });
//! ```

use crate::rng::Xoshiro256;
use std::ops::Range;

/// Case-generation handle passed to property closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Trace of the draws made in this case (for reporting).
    pub trace: Vec<String>,
    /// Shrink scale in (0, 1]: sizes and magnitudes contract towards
    /// minimal cases as the framework retries a failing seed.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self { rng: Xoshiro256::seed_from_u64(seed), trace: Vec::new(), scale }
    }

    /// Uniform f64 in `[lo, hi)`, contracted towards the midpoint under
    /// shrinking.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo) * self.scale;
        let v = self.rng.uniform_in(mid - half, mid + half);
        self.trace.push(format!("f64[{lo},{hi}) = {v}"));
        v
    }

    /// Positive f64 log-uniform in `[lo, hi)` — natural for scale
    /// hyperparameters.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        let v = (self.rng.uniform_in(lo.ln(), lo.ln() + (hi.ln() - lo.ln()) * self.scale)).exp();
        self.trace.push(format!("logu[{lo},{hi}) = {v}"));
        v
    }

    /// usize in a range, contracted towards `range.start` under shrinking.
    pub fn usize(&mut self, range: Range<usize>) -> usize {
        assert!(range.end > range.start);
        let span = ((range.end - range.start) as f64 * self.scale).ceil() as usize;
        let span = span.max(1);
        let v = range.start + self.rng.below(span);
        self.trace.push(format!("usize[{:?}) = {v}", range));
        v
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        let v = self.rng.normal() * self.scale;
        self.trace.push(format!("normal = {v}"));
        v
    }

    /// Vector of uniforms with random length in `len`.
    pub fn vec_f64(&mut self, len: Range<usize>, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Bool with probability `p` of true.
    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.uniform() < p;
        self.trace.push(format!("bool({p}) = {v}"));
        v
    }

    /// Access the raw RNG (for domain-specific draws).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Outcome of a property over one generated case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop`. On failure, retry the failing seed
/// at geometrically decreasing scales (bisection shrinking) and panic with
/// the smallest failing case's trace.
pub fn property<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> CaseResult,
{
    // Deterministic per-property seeding: hash the name so adding a new
    // property elsewhere doesn't shift this one's cases.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // shrink: same seed, smaller scales
            let mut smallest = (msg, g.trace);
            for k in 1..=6 {
                let scale = 1.0 / (1 << k) as f64;
                let mut g2 = Gen::new(seed, scale);
                if let Err(m2) = prop(&mut g2) {
                    smallest = (m2, g2.trace);
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {}\n  draws:\n    {}",
                smallest.0,
                smallest.1.join("\n    ")
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("abs is non-negative", 200, |g| {
            let x = g.f64(-100.0, 100.0);
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("abs({x}) < 0"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_trace() {
        property("always fails", 10, |g| {
            let _ = g.f64(0.0, 1.0);
            Err("nope".to_string())
        });
    }

    #[test]
    fn shrinking_reduces_magnitude() {
        // A property failing only for |x| > 10 should report a shrunk case
        // (scale contraction pulls values towards the midpoint 0).
        let result = std::panic::catch_unwind(|| {
            property("fails for big x", 50, |g| {
                let x = g.f64(-100.0, 100.0);
                if x.abs() <= 10.0 {
                    Ok(())
                } else {
                    Err(format!("big {x}"))
                }
            });
        });
        // It must fail (values >10 occur with prob ~0.9 per case)...
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // ...and the reported case should be from a shrunk scale: values at
        // scale 1/2 are within ±50, at 1/4 within ±25, etc. We only assert
        // the shrink machinery ran by checking the trace exists.
        assert!(msg.contains("draws:"), "panic message carries the trace: {msg}");
    }

    #[test]
    fn deterministic_given_name() {
        let mut first: Vec<f64> = Vec::new();
        property("det check", 5, |g| {
            first.push(g.f64(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        property("det check", 5, |g| {
            second.push(g.f64(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn generators_respect_ranges() {
        property("ranges", 300, |g| {
            let u = g.usize(3..17);
            if !(3..17).contains(&u) {
                return Err(format!("usize out of range: {u}"));
            }
            let x = g.log_uniform(1e-3, 1e3);
            if !(1e-3..1e3).contains(&x) {
                return Err(format!("logu out of range: {x}"));
            }
            let v = g.vec_f64(0..5, -1.0, 1.0);
            if v.len() >= 5 || v.iter().any(|x| !(-1.0..1.0).contains(x)) {
                return Err("vec constraint violated".to_string());
            }
            Ok(())
        });
    }
}
