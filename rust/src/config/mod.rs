//! Run configuration: a TOML-subset parser (no `serde` offline) plus the
//! typed [`RunConfig`] consumed by the CLI and examples.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! float, integer, boolean and flat-array values, `#` comments.

mod toml;

pub use toml::{TomlDoc, TomlValue};

use crate::coordinator::{PipelineConfig, Roster};
use crate::nested::NestedOptions;
use crate::optimize::{CgOptions, MultistartOptions};
use crate::priors::ScalePrior;

/// Typed configuration for a gpfast run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub seed: u64,
    pub models: Vec<String>,
    pub sigma_n: f64,
    pub restarts: usize,
    pub nlive: usize,
    pub run_nested: bool,
    pub backend: String,
    pub workers: usize,
    /// Linalg/assembly thread budget; 0 means "auto" (`GPFAST_THREADS`
    /// env var, else the machine's available parallelism).
    pub threads: usize,
    pub artifacts_dir: String,
    /// `[serve] window` — sliding-window capacity for the serving
    /// session (0 = unbounded; ≥ 2 bounds every cached factor).
    pub serve_window: usize,
    /// `[serve] refresh_every` — cold-refactorise the windowed factors
    /// after this many evictions (0 = never; only meaningful with a
    /// window).
    pub serve_refresh_every: usize,
    /// `[serve] cond_limit` — spectral-condition estimate above which a
    /// slot latches **degraded** into `needs_retrain` (0 = the library
    /// default, [`crate::coordinator::COND_RETRAIN_LIMIT`]).
    pub serve_cond_limit: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            seed: 20160125, // the paper's DOI date
            models: vec!["k1".into(), "k2".into()],
            sigma_n: 0.1,
            restarts: 10,
            nlive: 400,
            run_nested: false,
            backend: "auto".into(),
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            threads: 0,
            artifacts_dir: "artifacts".into(),
            serve_window: 0,
            serve_refresh_every: 64,
            serve_cond_limit: 0.0,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file; missing keys keep defaults.
    pub fn from_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Self::default();
        if let Some(v) = doc.get("run", "seed") {
            cfg.seed = v.as_int().ok_or_else(|| anyhow::anyhow!("run.seed must be int"))? as u64;
        }
        if let Some(v) = doc.get("run", "models") {
            cfg.models = v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("run.models must be an array"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(String::from)
                        .ok_or_else(|| anyhow::anyhow!("model names must be strings"))
                })
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.get("run", "sigma_n") {
            cfg.sigma_n = v.as_float().ok_or_else(|| anyhow::anyhow!("run.sigma_n"))?;
        }
        if let Some(v) = doc.get("train", "restarts") {
            cfg.restarts = v.as_int().ok_or_else(|| anyhow::anyhow!("train.restarts"))? as usize;
        }
        if let Some(v) = doc.get("nested", "nlive") {
            cfg.nlive = v.as_int().ok_or_else(|| anyhow::anyhow!("nested.nlive"))? as usize;
        }
        if let Some(v) = doc.get("nested", "enabled") {
            cfg.run_nested = v.as_bool().ok_or_else(|| anyhow::anyhow!("nested.enabled"))?;
        }
        if let Some(v) = doc.get("runtime", "backend") {
            cfg.backend =
                v.as_str().ok_or_else(|| anyhow::anyhow!("runtime.backend"))?.to_string();
        }
        if let Some(v) = doc.get("runtime", "workers") {
            cfg.workers = v.as_int().ok_or_else(|| anyhow::anyhow!("runtime.workers"))? as usize;
        }
        if let Some(v) = doc.get("runtime", "threads") {
            let t = v.as_int().ok_or_else(|| anyhow::anyhow!("runtime.threads"))?;
            anyhow::ensure!(t >= 0, "runtime.threads must be >= 0 (0 = auto), got {t}");
            cfg.threads = t as usize;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir") {
            cfg.artifacts_dir =
                v.as_str().ok_or_else(|| anyhow::anyhow!("runtime.artifacts_dir"))?.to_string();
        }
        if let Some(v) = doc.get("serve", "window") {
            let w = v.as_int().ok_or_else(|| anyhow::anyhow!("serve.window"))?;
            anyhow::ensure!(w >= 0, "serve.window must be >= 0 (0 = unbounded), got {w}");
            cfg.serve_window = w as usize;
        }
        if let Some(v) = doc.get("serve", "refresh_every") {
            let r = v.as_int().ok_or_else(|| anyhow::anyhow!("serve.refresh_every"))?;
            anyhow::ensure!(r >= 0, "serve.refresh_every must be >= 0 (0 = never), got {r}");
            cfg.serve_refresh_every = r as usize;
        }
        if let Some(v) = doc.get("serve", "cond_limit") {
            let c = v.as_float().ok_or_else(|| anyhow::anyhow!("serve.cond_limit"))?;
            anyhow::ensure!(
                c == 0.0 || c > 1.0,
                "serve.cond_limit must be 0 (library default) or > 1, got {c}"
            );
            cfg.serve_cond_limit = c;
        }
        Ok(cfg)
    }

    /// The condition limit this config describes (`0` means the library
    /// default, [`crate::coordinator::COND_RETRAIN_LIMIT`]).
    pub fn cond_limit(&self) -> f64 {
        if self.serve_cond_limit > 1.0 {
            self.serve_cond_limit
        } else {
            crate::coordinator::COND_RETRAIN_LIMIT
        }
    }

    /// The sliding-window policy this config describes, if any
    /// (`serve.window = 0` means serve unbounded).
    pub fn window_policy(&self) -> Option<crate::coordinator::WindowPolicy> {
        (self.serve_window > 0).then(|| crate::coordinator::WindowPolicy {
            max_points: self.serve_window,
            refresh_every: self.serve_refresh_every,
        })
    }

    /// The execution context this config describes: `threads = 0` means
    /// auto (`GPFAST_THREADS` env var, else machine parallelism).
    pub fn exec(&self) -> crate::runtime::ExecutionContext {
        if self.threads == 0 {
            crate::runtime::ExecutionContext::from_env()
        } else {
            crate::runtime::ExecutionContext::new(self.threads)
        }
    }

    /// The model roster this config names (validated, deduplicated).
    pub fn roster(&self) -> crate::Result<Roster> {
        Roster::from_names(&self.models)
    }

    /// Materialise the pipeline configuration.
    pub fn pipeline(&self) -> crate::Result<PipelineConfig> {
        let models = self.roster()?.specs().to_vec();
        Ok(PipelineConfig {
            models,
            sigma_n: self.sigma_n,
            train: crate::coordinator::TrainOptions {
                multistart: MultistartOptions {
                    restarts: self.restarts,
                    cg: CgOptions::default(),
                    ..Default::default()
                },
                extra_starts: Vec::new(),
            },
            scale_prior: ScalePrior::default(),
            run_nested: self.run_nested,
            nested: NestedOptions { nlive: self.nlive, ..Default::default() },
            workers: self.workers,
            exec: self.exec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# gpfast run configuration
[run]
seed = 42
models = ["k1", "k2", "k3"]
sigma_n = 0.01

[train]
restarts = 5

[nested]
enabled = true
nlive = 250

[runtime]
backend = "native"
workers = 2
"#;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.models, vec!["k1", "k2", "k3"]);
        assert_eq!(cfg.sigma_n, 0.01);
        assert_eq!(cfg.restarts, 5);
        assert!(cfg.run_nested);
        assert_eq!(cfg.nlive, 250);
        assert_eq!(cfg.backend, "native");
        assert_eq!(cfg.workers, 2);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let cfg = RunConfig::from_toml("[run]\nseed = 1\n").unwrap();
        assert_eq!(cfg.seed, 1);
        assert_eq!(cfg.models, vec!["k1", "k2"]);
        assert_eq!(cfg.restarts, 10);
    }

    #[test]
    fn pipeline_materialises() {
        let cfg = RunConfig::from_toml(SAMPLE).unwrap();
        let p = cfg.pipeline().unwrap();
        assert_eq!(p.models.len(), 3);
        assert_eq!(p.train.multistart.restarts, 5);
        assert!(p.run_nested);
    }

    #[test]
    fn threads_key_parses_and_rejects_negatives() {
        let cfg = RunConfig::from_toml("[runtime]\nthreads = 3\n").unwrap();
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.exec().threads(), 3);
        let auto = RunConfig::from_toml("[runtime]\nthreads = 0\n").unwrap();
        assert!(auto.exec().threads() >= 1);
        assert!(RunConfig::from_toml("[runtime]\nthreads = -1\n").is_err());
    }

    #[test]
    fn bad_model_rejected_at_pipeline() {
        let cfg = RunConfig::from_toml("[run]\nmodels = [\"nope\"]\n").unwrap();
        assert!(cfg.pipeline().is_err());
    }

    #[test]
    fn serve_window_keys_parse_and_validate() {
        let cfg =
            RunConfig::from_toml("[serve]\nwindow = 500\nrefresh_every = 32\n").unwrap();
        assert_eq!(cfg.serve_window, 500);
        assert_eq!(cfg.serve_refresh_every, 32);
        let p = cfg.window_policy().expect("window set");
        assert_eq!(p.max_points, 500);
        assert_eq!(p.refresh_every, 32);
        // defaults: unbounded serving, no policy materialised
        let d = RunConfig::from_toml("[run]\nseed = 1\n").unwrap();
        assert_eq!(d.serve_window, 0);
        assert!(d.window_policy().is_none());
        assert!(RunConfig::from_toml("[serve]\nwindow = -3\n").is_err());
        assert!(RunConfig::from_toml("[serve]\nrefresh_every = -1\n").is_err());
    }

    #[test]
    fn serve_cond_limit_parses_and_validates() {
        let cfg = RunConfig::from_toml("[serve]\ncond_limit = 1e10\n").unwrap();
        assert_eq!(cfg.serve_cond_limit, 1e10);
        assert_eq!(cfg.cond_limit(), 1e10);
        // 0 / unset → library default
        let d = RunConfig::from_toml("[run]\nseed = 1\n").unwrap();
        assert_eq!(d.serve_cond_limit, 0.0);
        assert_eq!(d.cond_limit(), crate::coordinator::COND_RETRAIN_LIMIT);
        // a limit inside (0, 1] can never latch meaningfully — rejected
        assert!(RunConfig::from_toml("[serve]\ncond_limit = 0.5\n").is_err());
        assert!(RunConfig::from_toml("[serve]\ncond_limit = -2.0\n").is_err());
    }
}
