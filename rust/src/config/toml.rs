//! Minimal TOML-subset parser: sections, scalar values, flat arrays,
//! comments. Enough for run configuration files; not a general TOML
//! implementation (no nested tables, no multi-line strings, no dates).

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (TOML semantic convenience).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: `(section, key) → value`. Keys before any section
/// header live in section `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let v = parse_value(value.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.values.insert((section.clone(), key.trim().to_string()), v);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.values.get(&(section.to_string(), key.to_string()))
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but safe for our subset: a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> crate::Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

/// Split a flat array body on commas (no nested arrays in our subset).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let doc = TomlDoc::parse(
            "top = 1\n[a]\nx = 1.5\ny = \"hi\"\nz = true\n[b]\nx = -3\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int(), Some(1));
        assert_eq!(doc.get("a", "x").unwrap().as_float(), Some(1.5));
        assert_eq!(doc.get("a", "y").unwrap().as_str(), Some("hi"));
        assert_eq!(doc.get("a", "z").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("b", "x").unwrap().as_int(), Some(-3));
        assert!(doc.get("a", "missing").is_none());
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse("[s]\nm = [\"k1\", \"k2\"]\nn = [1, 2, 3]\ne = []\n").unwrap();
        let m = doc.get("s", "m").unwrap().as_array().unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[1].as_str(), Some("k2"));
        let n = doc.get("s", "n").unwrap().as_array().unwrap();
        assert_eq!(n[2].as_int(), Some(3));
        assert!(doc.get("s", "e").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn comments_stripped() {
        let doc = TomlDoc::parse("# header\nx = 5 # trailing\ns = \"a # not comment\"\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_int(), Some(5));
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = TomlDoc::parse("a = 2\nb = 2.0\nc = 1e3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int(), Some(2));
        assert!(doc.get("", "b").unwrap().as_int().is_none());
        assert_eq!(doc.get("", "b").unwrap().as_float(), Some(2.0));
        assert_eq!(doc.get("", "c").unwrap().as_float(), Some(1000.0));
        // int promotes to float
        assert_eq!(doc.get("", "a").unwrap().as_float(), Some(2.0));
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("x = @bad\n").is_err());
    }
}
