//! Nelder–Mead simplex maximiser (derivative-free).
//!
//! Exists for the "value of the analytic gradient" ablation
//! (`benches/ablations.rs`): the paper's §2(a) point is that the gradient
//! comes almost free once ln P is evaluated, making gradient-based search
//! far cheaper in likelihood evaluations than derivative-free search.

use crate::priors::BoxPrior;

use super::Objective;

/// Options for Nelder–Mead.
#[derive(Clone, Copy, Debug)]
pub struct NmOptions {
    /// Initial simplex scale as a fraction of each coordinate's range.
    pub init_scale: f64,
    /// Convergence: spread of simplex values.
    pub f_tol: f64,
    pub max_iters: usize,
}

impl Default for NmOptions {
    fn default() -> Self {
        Self { init_scale: 0.05, f_tol: 1e-9, max_iters: 2000 }
    }
}

/// Maximise `obj` inside `prior` from `x0`. Returns `(θ̂, f̂)`.
pub fn maximise_neldermead(
    obj: &mut dyn Objective,
    prior: &BoxPrior,
    x0: &[f64],
    opts: &NmOptions,
) -> crate::Result<(Vec<f64>, f64)> {
    let n = obj.dim();
    let eval = |x: &mut Vec<f64>, obj: &mut dyn Objective| -> crate::Result<f64> {
        prior.project(x);
        obj.value(x)
    };
    // initial simplex
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let mut base = x0.to_vec();
    let f0 = eval(&mut base, obj)?;
    simplex.push((base.clone(), f0));
    for i in 0..n {
        let mut v = base.clone();
        let (lo, hi) = prior.bounds[i];
        v[i] += opts.init_scale * (hi - lo);
        let f = eval(&mut v, obj)?;
        simplex.push((v, f));
    }
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);
    for _ in 0..opts.max_iters {
        // sort descending (maximisation: best first; NaN vertices last)
        simplex.sort_by(|a, b| crate::util::desc_nan_last(a.1, b.1));
        let spread = simplex[0].1 - simplex[n].1;
        if spread.abs() < opts.f_tol * (1.0 + simplex[0].1.abs()) {
            break;
        }
        // centroid of all but worst
        let mut centroid = vec![0.0; n];
        for (v, _) in &simplex[..n] {
            for i in 0..n {
                centroid[i] += v[i] / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let reflect = |t: f64| -> Vec<f64> {
            (0..n).map(|i| centroid[i] + t * (centroid[i] - worst.0[i])).collect()
        };
        let mut xr = reflect(alpha);
        let fr = eval(&mut xr, obj)?;
        if fr > simplex[0].1 {
            // try expansion
            let mut xe = reflect(gamma);
            let fe = eval(&mut xe, obj)?;
            simplex[n] = if fe > fr { (xe, fe) } else { (xr, fr) };
        } else if fr > simplex[n - 1].1 {
            simplex[n] = (xr, fr);
        } else {
            // contraction
            let mut xc = reflect(-rho);
            let fc = eval(&mut xc, obj)?;
            if fc > worst.1 {
                simplex[n] = (xc, fc);
            } else {
                // shrink towards best
                let best = simplex[0].0.clone();
                for item in simplex.iter_mut().skip(1) {
                    let mut v: Vec<f64> = item
                        .0
                        .iter()
                        .zip(&best)
                        .map(|(vi, bi)| bi + sigma * (vi - bi))
                        .collect();
                    let f = eval(&mut v, obj)?;
                    *item = (v, f);
                }
            }
        }
    }
    simplex.sort_by(|a, b| crate::util::desc_nan_last(a.1, b.1));
    let best = simplex.remove(0);
    Ok((best.0, best.1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::FnObjective;

    #[test]
    fn finds_quadratic_max() {
        let mut obj = FnObjective::new(
            2,
            |t: &[f64]| Ok(-(t[0] - 1.0).powi(2) - (t[1] + 2.0).powi(2)),
            |_: &[f64]| unreachable!("derivative-free"),
        );
        let prior = BoxPrior { bounds: vec![(-10.0, 10.0); 2], constraints: vec![] };
        let (x, f) = maximise_neldermead(&mut obj, &prior, &[5.0, 5.0], &NmOptions::default())
            .unwrap();
        assert!((x[0] - 1.0).abs() < 1e-3, "{x:?} f={f}");
        assert!((x[1] + 2.0).abs() < 1e-3);
    }

    #[test]
    fn uses_more_evals_than_cg_on_same_problem() {
        // the ablation claim in miniature
        let f = |t: &[f64]| -(t[0] - 2.0).powi(2) - 2.0 * (t[1] + 1.0).powi(2);
        let prior = BoxPrior { bounds: vec![(-100.0, 100.0); 2], constraints: vec![] };
        let mut nm_obj =
            FnObjective::new(2, |t: &[f64]| Ok(f(t)), |_: &[f64]| unreachable!());
        let _ = maximise_neldermead(&mut nm_obj, &prior, &[50.0, 50.0], &NmOptions::default())
            .unwrap();
        let mut cg_obj = FnObjective::new(
            2,
            |t: &[f64]| Ok(f(t)),
            |t: &[f64]| Ok((f(t), vec![-2.0 * (t[0] - 2.0), -4.0 * (t[1] + 1.0)])),
        );
        let _ = crate::optimize::maximise_cg(
            &mut cg_obj,
            &prior,
            &[50.0, 50.0],
            &crate::optimize::CgOptions::default(),
        )
        .unwrap();
        assert!(
            nm_obj.evals() > cg_obj.evals(),
            "NM {} vs CG {}",
            nm_obj.evals(),
            cg_obj.evals()
        );
    }

    #[test]
    fn stays_in_box() {
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(t[0]),
            |_: &[f64]| unreachable!(),
        );
        let prior = BoxPrior { bounds: vec![(0.0, 3.0)], constraints: vec![] };
        let (x, _) = maximise_neldermead(&mut obj, &prior, &[1.0], &NmOptions::default()).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-6);
    }
}
