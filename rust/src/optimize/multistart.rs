//! Multistart driver — §3(a): "In order to guard against the possibility
//! of the maximisation routines becoming trapped in local maxima … the
//! algorithm was run multiple times from randomly selected starting
//! positions. The typical number of runs required to find the global
//! maximum was ∼ 10."

use crate::priors::BoxPrior;
use crate::rng::Xoshiro256;

use super::{maximise_cg, CgOptions, Objective};

/// Options for the multistart driver.
#[derive(Clone, Copy, Debug)]
pub struct MultistartOptions {
    /// Number of random restarts (paper: ~10).
    pub restarts: usize,
    /// Two peaks closer than this (∞-norm) are considered the same mode.
    pub dedupe_tol: f64,
    pub cg: CgOptions,
}

impl Default for MultistartOptions {
    fn default() -> Self {
        Self { restarts: 10, dedupe_tol: 1e-3, cg: CgOptions::default() }
    }
}

/// One restart's result.
#[derive(Clone, Debug)]
pub struct StartOutcome {
    pub start: Vec<f64>,
    pub theta: Vec<f64>,
    pub value: f64,
    pub converged: bool,
    pub iterations: usize,
}

/// Aggregate outcome.
#[derive(Clone, Debug)]
pub struct MultistartOutcome {
    /// The best (global, we hope) peak.
    pub best: StartOutcome,
    /// Every restart, best first.
    pub all: Vec<StartOutcome>,
    /// Number of *distinct* modes found (after dedupe) — the paper's
    /// multimodality diagnostic for the flagged (k₂, n=30) failure case.
    pub n_modes: usize,
}

/// Run `opts.restarts` CG maximisations from prior-sampled starts.
pub fn multistart(
    obj: &mut dyn Objective,
    prior: &BoxPrior,
    opts: &MultistartOptions,
    rng: &mut Xoshiro256,
) -> crate::Result<MultistartOutcome> {
    anyhow::ensure!(opts.restarts > 0, "need at least one restart");
    let mut all = Vec::with_capacity(opts.restarts);
    for _ in 0..opts.restarts {
        let start = prior.sample(rng);
        match maximise_cg(obj, prior, &start, &opts.cg) {
            Ok(out) => all.push(StartOutcome {
                start,
                theta: out.theta,
                value: out.value,
                converged: out.converged,
                iterations: out.iterations,
            }),
            Err(_) => {
                // a start that lands on a non-PD covariance region is just
                // discarded — the paper's code would equally reject it
                continue;
            }
        }
    }
    anyhow::ensure!(!all.is_empty(), "every restart failed (covariance never PD)");
    // NaN-safe: a restart that converged onto a NaN objective value ranks
    // last instead of panicking the driver
    all.sort_by(|a, b| crate::util::desc_nan_last(a.value, b.value));
    // count distinct modes
    let mut modes: Vec<&[f64]> = Vec::new();
    for s in &all {
        let dup = modes.iter().any(|m| {
            m.iter().zip(&s.theta).fold(0.0f64, |acc, (a, b)| acc.max((a - b).abs()))
                < opts.dedupe_tol
        });
        if !dup {
            modes.push(&s.theta);
        }
    }
    let n_modes = modes.len();
    Ok(MultistartOutcome { best: all[0].clone(), all, n_modes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::FnObjective;

    /// Double-well: two maxima at x = ±2, global at +2 (value 1 vs 0.5).
    fn double_well(t: &[f64]) -> f64 {
        let x = t[0];
        let peak = |c: f64, h: f64| h * (-(x - c) * (x - c)).exp();
        peak(2.0, 1.0) + peak(-2.0, 0.5)
    }

    fn double_well_grad(t: &[f64]) -> Vec<f64> {
        let x = t[0];
        let dpeak = |c: f64, h: f64| -2.0 * (x - c) * h * (-(x - c) * (x - c)).exp();
        vec![dpeak(2.0, 1.0) + dpeak(-2.0, 0.5)]
    }

    #[test]
    fn finds_global_mode_among_two() {
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(double_well(t)),
            |t: &[f64]| Ok((double_well(t), double_well_grad(t))),
        );
        let prior = BoxPrior { bounds: vec![(-6.0, 6.0)], constraints: vec![] };
        let mut rng = Xoshiro256::seed_from_u64(17);
        let out = multistart(&mut obj, &prior, &MultistartOptions::default(), &mut rng).unwrap();
        assert!((out.best.theta[0] - 2.0).abs() < 1e-3, "best {:?}", out.best.theta);
        assert!(out.n_modes >= 2, "should discover both wells, found {}", out.n_modes);
        assert!((out.best.value - 1.0).abs() < 1e-6);
    }

    #[test]
    fn results_sorted_descending() {
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(double_well(t)),
            |t: &[f64]| Ok((double_well(t), double_well_grad(t))),
        );
        let prior = BoxPrior { bounds: vec![(-6.0, 6.0)], constraints: vec![] };
        let mut rng = Xoshiro256::seed_from_u64(4);
        let out = multistart(&mut obj, &prior, &MultistartOptions::default(), &mut rng).unwrap();
        for w in out.all.windows(2) {
            assert!(w[0].value >= w[1].value);
        }
    }

    /// NaN for x < 0 (with a zero gradient, so CG "converges" right at the
    /// start and reports the NaN value); a single clean peak at x = 2
    /// otherwise.
    fn nan_left(t: &[f64]) -> f64 {
        let x = t[0];
        if x < 0.0 {
            f64::NAN
        } else {
            (-(x - 2.0) * (x - 2.0)).exp()
        }
    }

    fn nan_left_grad(t: &[f64]) -> Vec<f64> {
        let x = t[0];
        if x < 0.0 {
            vec![0.0]
        } else {
            vec![-2.0 * (x - 2.0) * nan_left(t)]
        }
    }

    #[test]
    fn nan_objective_ranks_last_instead_of_panicking() {
        // regression: a restart that converges onto a NaN objective value
        // used to panic the `partial_cmp().unwrap()` ranking sort; it must
        // complete and rank the NaN outcomes strictly last
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(nan_left(t)),
            |t: &[f64]| Ok((nan_left(t), nan_left_grad(t))),
        );
        let prior = BoxPrior { bounds: vec![(-6.0, 6.0)], constraints: vec![] };
        let mut rng = Xoshiro256::seed_from_u64(11);
        let opts = MultistartOptions { restarts: 16, ..Default::default() };
        let out = multistart(&mut obj, &prior, &opts, &mut rng).unwrap();
        assert!(out.best.value.is_finite(), "best value is {}", out.best.value);
        assert!((out.best.theta[0] - 2.0).abs() < 1e-3, "best {:?}", out.best.theta);
        assert!(
            out.all.iter().any(|s| s.value.is_nan()),
            "seeded starts must include at least one NaN-region restart"
        );
        let first_nan = out.all.iter().position(|s| s.value.is_nan()).unwrap();
        assert!(
            out.all[first_nan..].iter().all(|s| s.value.is_nan()),
            "every NaN outcome must rank after every finite one"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut obj = FnObjective::new(
                1,
                |t: &[f64]| Ok(double_well(t)),
                |t: &[f64]| Ok((double_well(t), double_well_grad(t))),
            );
            let prior = BoxPrior { bounds: vec![(-6.0, 6.0)], constraints: vec![] };
            let mut rng = Xoshiro256::seed_from_u64(seed);
            multistart(&mut obj, &prior, &MultistartOptions::default(), &mut rng)
                .unwrap()
                .best
                .theta
        };
        assert_eq!(run(5), run(5));
    }
}
