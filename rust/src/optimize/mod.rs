//! Numerical maximisation of the hyperlikelihood — §2(a) of the paper:
//! "The maximisation process may be accelerated if the gradient of the
//! hyperlikelihood is known and a gradient-based algorithm, such as a
//! conjugate gradient method, can be used."
//!
//! * [`cg`] — Polak–Ribière+ conjugate gradient with a Wolfe line search,
//!   projected onto the hyperprior box (the paper's optimiser);
//! * [`neldermead`] — derivative-free simplex fallback, used by the
//!   "value of the gradient" ablation benchmark;
//! * [`multistart`] — repeated runs from random prior draws (the paper:
//!   "the algorithm was run multiple times from randomly selected starting
//!   positions. The typical number of runs required … was ∼ 10").

mod cg;
mod neldermead;
mod multistart;

pub use cg::{maximise_cg, CgOptions, CgOutcome};
pub use multistart::{multistart, MultistartOptions, MultistartOutcome, StartOutcome};
pub use neldermead::{maximise_neldermead, NmOptions};

use crate::priors::BoxPrior;

/// A maximisation objective with gradient. Implementations count their own
/// evaluations (the paper's headline speed metric is likelihood-evaluation
/// counts).
pub trait Objective {
    fn dim(&self) -> usize;
    /// Value only.
    fn value(&mut self, theta: &[f64]) -> crate::Result<f64>;
    /// Value and gradient.
    fn value_grad(&mut self, theta: &[f64]) -> crate::Result<(f64, Vec<f64>)>;
}

/// Wraps closures into an [`Objective`] and counts evaluations.
pub struct FnObjective<F, G>
where
    F: FnMut(&[f64]) -> crate::Result<f64>,
    G: FnMut(&[f64]) -> crate::Result<(f64, Vec<f64>)>,
{
    dim: usize,
    f: F,
    g: G,
    /// Number of value-only evaluations.
    pub n_value: usize,
    /// Number of value+gradient evaluations.
    pub n_grad: usize,
}

impl<F, G> FnObjective<F, G>
where
    F: FnMut(&[f64]) -> crate::Result<f64>,
    G: FnMut(&[f64]) -> crate::Result<(f64, Vec<f64>)>,
{
    pub fn new(dim: usize, f: F, g: G) -> Self {
        Self { dim, f, g, n_value: 0, n_grad: 0 }
    }

    /// Total objective evaluations (the paper counts these).
    pub fn evals(&self) -> usize {
        self.n_value + self.n_grad
    }
}

impl<F, G> Objective for FnObjective<F, G>
where
    F: FnMut(&[f64]) -> crate::Result<f64>,
    G: FnMut(&[f64]) -> crate::Result<(f64, Vec<f64>)>,
{
    fn dim(&self) -> usize {
        self.dim
    }

    fn value(&mut self, theta: &[f64]) -> crate::Result<f64> {
        self.n_value += 1;
        (self.f)(theta)
    }

    fn value_grad(&mut self, theta: &[f64]) -> crate::Result<(f64, Vec<f64>)> {
        self.n_grad += 1;
        (self.g)(theta)
    }
}

/// Project the gradient at a box boundary: zero the components that point
/// out of the feasible box (standard gradient-projection optimality
/// measure for bound-constrained problems).
pub fn project_gradient(theta: &[f64], grad: &mut [f64], prior: &BoxPrior) {
    const EDGE: f64 = 1e-12;
    for i in 0..theta.len() {
        let (lo, hi) = prior.bounds[i];
        if (theta[i] - lo).abs() <= EDGE * (1.0 + lo.abs()) && grad[i] < 0.0 {
            grad[i] = 0.0;
        }
        if (theta[i] - hi).abs() <= EDGE * (1.0 + hi.abs()) && grad[i] > 0.0 {
            grad[i] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_objective_counts() {
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(-t[0] * t[0]),
            |t: &[f64]| Ok((-t[0] * t[0], vec![-2.0 * t[0]])),
        );
        let _ = obj.value(&[1.0]).unwrap();
        let _ = obj.value_grad(&[1.0]).unwrap();
        let _ = obj.value_grad(&[2.0]).unwrap();
        assert_eq!(obj.n_value, 1);
        assert_eq!(obj.n_grad, 2);
        assert_eq!(obj.evals(), 3);
    }

    #[test]
    fn gradient_projection_zeroes_outward_components() {
        let prior = BoxPrior { bounds: vec![(0.0, 1.0), (0.0, 1.0)], constraints: vec![] };
        let theta = [0.0, 0.5];
        let mut g = vec![-3.0, 2.0];
        project_gradient(&theta, &mut g, &prior);
        assert_eq!(g, vec![0.0, 2.0]); // outward at lower bound removed
        let theta = [1.0, 0.5];
        let mut g = vec![5.0, -2.0];
        project_gradient(&theta, &mut g, &prior);
        assert_eq!(g, vec![0.0, -2.0]);
    }
}
