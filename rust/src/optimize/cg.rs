//! Bound-constrained Polak–Ribière+ conjugate-gradient **maximiser**.
//!
//! The search direction is the PR+ conjugate direction of the *projected*
//! gradient; every trial point of the line search is projected back into
//! the prior box (and onto the ordering constraints), making this a
//! projected-CG scheme. β < 0 or a non-ascent direction triggers a
//! steepest-ascent restart — the classic safeguard that gives PR+ its
//! global-convergence behaviour.

use crate::linalg::{axpy, dot, norm2};
use crate::priors::BoxPrior;

use super::{project_gradient, Objective};

/// Options for the CG maximiser.
#[derive(Clone, Copy, Debug)]
pub struct CgOptions {
    /// Stop when the ∞-norm of the projected gradient falls below this.
    pub grad_tol: f64,
    /// Stop when the objective improves by less than this across an
    /// iteration (scaled by 1+|f|).
    pub f_tol: f64,
    /// Maximum CG iterations.
    pub max_iters: usize,
    /// Armijo parameter c₁.
    pub c1: f64,
    /// Curvature (Wolfe) parameter c₂.
    pub c2: f64,
    /// Maximum line-search trials per iteration.
    pub max_ls: usize,
}

impl Default for CgOptions {
    /// Tolerances tuned so a typical profiled-hyperlikelihood run lands
    /// within ~1e-3 nats of the peak in ≲150 evaluations (the paper's
    /// "<100 evaluations" regime) — tighter tolerances sharpen θ̂ far
    /// beyond what the Laplace evidence can resolve while multiplying
    /// the evaluation budget (EXPERIMENTS.md §Perf).
    fn default() -> Self {
        Self { grad_tol: 3e-5, f_tol: 1e-9, max_iters: 120, c1: 1e-4, c2: 0.4, max_ls: 16 }
    }
}

/// Result of one CG run.
#[derive(Clone, Debug)]
pub struct CgOutcome {
    pub theta: Vec<f64>,
    pub value: f64,
    pub iterations: usize,
    /// Why the run stopped.
    pub converged: bool,
    /// ∞-norm of the final projected gradient.
    pub grad_norm: f64,
}

/// Maximise `obj` inside `prior` starting from `x0` (projected if needed).
pub fn maximise_cg(
    obj: &mut dyn Objective,
    prior: &BoxPrior,
    x0: &[f64],
    opts: &CgOptions,
) -> crate::Result<CgOutcome> {
    let n = obj.dim();
    anyhow::ensure!(x0.len() == n, "x0 dimension mismatch");
    let mut x = x0.to_vec();
    prior.project(&mut x);

    let (mut f, mut g) = obj.value_grad(&x)?;
    project_gradient(&x, &mut g, prior);
    let mut d = g.clone(); // ascent direction
    let mut g_prev = g.clone();
    let mut prev_step: Option<f64> = None;

    let mut iterations = 0;
    let mut converged = false;
    for _ in 0..opts.max_iters {
        iterations += 1;
        let gnorm = g.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if gnorm < opts.grad_tol {
            converged = true;
            break;
        }
        // ensure ascent; restart on failure
        let dg = dot(&d, &g);
        if dg <= 0.0 {
            d.copy_from_slice(&g);
        }
        // line search for f(project(x + a d)) satisfying Armijo+curvature
        let (a, f_new, x_new, g_new) =
            line_search(obj, prior, &x, f, &g, &d, prev_step, opts)?;
        if a > 0.0 {
            prev_step = Some(a);
        }
        if a == 0.0 {
            // no progress along d. If d was (numerically) the gradient
            // direction already, we are at a stationary/vertex point; else
            // restart along the gradient and retry once.
            let cos = dot(&d, &g) / (norm2(&d) * norm2(&g)).max(1e-300);
            if cos >= 0.999 {
                converged = gnorm < 1e3 * opts.grad_tol;
                break;
            }
            d.copy_from_slice(&g);
            continue;
        }
        let df = f_new - f;
        x = x_new;
        f = f_new;
        g_prev.copy_from_slice(&g);
        g = g_new;
        project_gradient(&x, &mut g, prior);
        if df.abs() < opts.f_tol * (1.0 + f.abs()) {
            converged = true;
            break;
        }
        // PR+ beta on projected gradients
        let denom = dot(&g_prev, &g_prev);
        let beta = if denom > 0.0 {
            ((dot(&g, &g) - dot(&g, &g_prev)) / denom).max(0.0)
        } else {
            0.0
        };
        for i in 0..n {
            d[i] = g[i] + beta * d[i];
        }
    }
    let grad_norm = g.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    Ok(CgOutcome { theta: x, value: f, iterations, converged, grad_norm })
}

/// Wolfe line search (maximisation form) with projection. Returns
/// `(step, f(x⁺), x⁺, ∇f(x⁺))`; step 0 means failure to improve.
#[allow(clippy::too_many_arguments)]
fn line_search(
    obj: &mut dyn Objective,
    prior: &BoxPrior,
    x: &[f64],
    f0: f64,
    g0: &[f64],
    d: &[f64],
    prev_step: Option<f64>,
    opts: &CgOptions,
) -> crate::Result<(f64, f64, Vec<f64>, Vec<f64>)> {
    let slope0 = dot(g0, d);
    if slope0 <= 0.0 {
        return Ok((0.0, f0, x.to_vec(), g0.to_vec()));
    }
    let trial = |a: f64| {
        let mut xt = x.to_vec();
        axpy(a, d, &mut xt);
        prior.project(&mut xt);
        xt
    };
    // initial step: reuse the last accepted step length (classic CG warm
    // start — saves ~2 evaluations/iteration), else scale to a sane
    // parameter change
    let dmax = d.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let default_a = (0.5 / dmax.max(1e-12)).min(1.0);
    let mut a = prev_step.map_or(default_a, |p| (2.0 * p).min(default_a.max(p)));
    let mut best: Option<(f64, f64, Vec<f64>, Vec<f64>)> = None;
    let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
    for _ in 0..opts.max_ls {
        let xt = trial(a);
        let (ft, mut gt) = obj.value_grad(&xt)?;
        if !ft.is_finite() {
            hi = a;
            a = 0.5 * (lo + if hi.is_finite() { hi } else { a });
            continue;
        }
        let armijo = ft >= f0 + opts.c1 * a * slope0;
        let slope_t = dot(&gt, d);
        let curvature = slope_t.abs() <= opts.c2 * slope0;
        if armijo && best.as_ref().map_or(true, |b| ft > b.1) {
            project_gradient(&xt, &mut gt, prior);
            best = Some((a, ft, xt.clone(), gt.clone()));
        }
        if armijo && curvature {
            break;
        }
        if !armijo {
            hi = a;
            a = 0.5 * (lo + hi);
        } else if slope_t > 0.0 {
            // still ascending: push right
            lo = a;
            a = if hi.is_finite() { 0.5 * (lo + hi) } else { 2.0 * a };
        } else {
            // overshot the peak
            hi = a;
            a = 0.5 * (lo + hi);
        }
        if hi.is_finite() && (hi - lo) < 1e-14 * (1.0 + lo) {
            break;
        }
    }
    match best {
        Some(b) => Ok(b),
        None => Ok((0.0, f0, x.to_vec(), g0.to_vec())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::FnObjective;

    fn unbounded_prior(n: usize) -> BoxPrior {
        BoxPrior { bounds: vec![(-1e6, 1e6); n], constraints: vec![] }
    }

    #[test]
    fn maximises_negative_quadratic() {
        // f = −(x−2)² − 2(y+1)², max at (2, −1)
        let mut obj = FnObjective::new(
            2,
            |t: &[f64]| Ok(-(t[0] - 2.0).powi(2) - 2.0 * (t[1] + 1.0).powi(2)),
            |t: &[f64]| {
                Ok((
                    -(t[0] - 2.0).powi(2) - 2.0 * (t[1] + 1.0).powi(2),
                    vec![-2.0 * (t[0] - 2.0), -4.0 * (t[1] + 1.0)],
                ))
            },
        );
        let out = maximise_cg(&mut obj, &unbounded_prior(2), &[10.0, 10.0], &CgOptions::default())
            .unwrap();
        assert!(out.converged);
        assert!((out.theta[0] - 2.0).abs() < 1e-4, "{:?}", out.theta);
        assert!((out.theta[1] + 1.0).abs() < 1e-4);
    }

    #[test]
    fn maximises_rosenbrock_flipped() {
        // max of −rosenbrock at (1,1); a hard curved valley for CG
        let f = |t: &[f64]| -(100.0 * (t[1] - t[0] * t[0]).powi(2) + (1.0 - t[0]).powi(2));
        let g = |t: &[f64]| {
            let df0 = -(-400.0 * t[0] * (t[1] - t[0] * t[0]) - 2.0 * (1.0 - t[0]));
            let df1 = -(200.0 * (t[1] - t[0] * t[0]));
            vec![df0, df1]
        };
        let mut obj = FnObjective::new(2, |t: &[f64]| Ok(f(t)), |t: &[f64]| Ok((f(t), g(t))));
        let opts = CgOptions { max_iters: 5000, grad_tol: 1e-7, f_tol: 1e-16, ..Default::default() };
        let out = maximise_cg(&mut obj, &unbounded_prior(2), &[-1.2, 1.0], &opts).unwrap();
        assert!(
            (out.theta[0] - 1.0).abs() < 1e-3 && (out.theta[1] - 1.0).abs() < 1e-3,
            "{:?} after {} iters (f = {})",
            out.theta,
            out.iterations,
            out.value
        );
    }

    #[test]
    fn respects_box_bounds() {
        // max of x+y over [0,1]² is the corner (1,1)
        let mut obj = FnObjective::new(
            2,
            |t: &[f64]| Ok(t[0] + t[1]),
            |t: &[f64]| Ok((t[0] + t[1], vec![1.0, 1.0])),
        );
        let prior = BoxPrior { bounds: vec![(0.0, 1.0), (0.0, 1.0)], constraints: vec![] };
        let out = maximise_cg(&mut obj, &prior, &[0.2, 0.3], &CgOptions::default()).unwrap();
        assert!((out.theta[0] - 1.0).abs() < 1e-9);
        assert!((out.theta[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn respects_ordering_constraint() {
        // max −(x−3)² − (y−0)² s.t. x ≤ y over big box: optimum x = y = 1.5
        let f = |t: &[f64]| -(t[0] - 3.0).powi(2) - t[1].powi(2);
        let mut obj = FnObjective::new(
            2,
            |t: &[f64]| Ok(f(t)),
            |t: &[f64]| Ok((f(t), vec![-2.0 * (t[0] - 3.0), -2.0 * t[1]])),
        );
        let prior = BoxPrior { bounds: vec![(-10.0, 10.0); 2], constraints: vec![(0, 1)] };
        let out = maximise_cg(&mut obj, &prior, &[0.0, 5.0], &CgOptions::default()).unwrap();
        assert!(prior.contains(&out.theta));
        assert!(
            (out.theta[0] - 1.5).abs() < 0.05 && (out.theta[1] - 1.5).abs() < 0.05,
            "{:?}",
            out.theta
        );
    }

    #[test]
    fn few_evals_on_easy_problem() {
        let mut obj = FnObjective::new(
            1,
            |t: &[f64]| Ok(-(t[0] - 0.5).powi(2)),
            |t: &[f64]| Ok((-(t[0] - 0.5).powi(2), vec![-2.0 * (t[0] - 0.5)])),
        );
        let out =
            maximise_cg(&mut obj, &unbounded_prior(1), &[40.0], &CgOptions::default()).unwrap();
        assert!(out.converged);
        assert!(obj.evals() < 60, "used {} evals", obj.evals());
    }
}
