//! erf family + lgamma.
//!
//! `erfinv` is required by the flat-prior reparametrisation of the
//! smoothness hyperparameters, eq. (3.5) of the paper:
//! `l_j = exp(μ + √2 σ_l erf⁻¹(2ξ_j))`.
//! `lgamma` is required by the marginalisation constant of eq. (2.18):
//! `ln[ (c/2) (2e/n)^{n/2} Γ(n/2) ]`.

// erf/erfc are computed through the regularised incomplete gamma functions
// P(1/2, x²) and Q(1/2, x²) (Numerical-Recipes-style `gser`/`gcf`):
// a power series where it converges fast (x² < 1.5) and a Lentz-style
// continued fraction elsewhere. This gives ~1 ulp relative accuracy on
// both tails, which the flat-prior transform (eq. 3.5) needs.

/// Series for the regularised lower incomplete gamma P(a, x), x < a+1.
fn gamma_p_series(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..300 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-17 {
            break;
        }
    }
    sum * (-x + a * x.ln() - lgamma(a)).exp()
}

/// Continued fraction for the regularised upper incomplete gamma Q(a, x),
/// x ≥ a+1 region (modified Lentz).
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..300 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - lgamma(a)).exp() * h
}

/// Error function, ~1 ulp relative accuracy.
pub fn erf(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    let ax = x.abs();
    let x2 = ax * ax;
    let v = if x2 < 1.5 {
        gamma_p_series(0.5, x2)
    } else {
        1.0 - gamma_q_cf(0.5, x2)
    };
    if x < 0.0 {
        -v
    } else {
        v
    }
}

/// Complementary error function, accurate in the far tail
/// (relative, not just absolute, accuracy for large `x`).
pub fn erfc(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x >= 0.0 {
        let x2 = x * x;
        if x2 < 1.5 {
            1.0 - gamma_p_series(0.5, x2)
        } else {
            gamma_q_cf(0.5, x2)
        }
    } else {
        2.0 - erfc(-x)
    }
}

/// Inverse error function on (-1, 1).
///
/// Hybrid: a central rational approximation refined by two Newton steps on
/// `erf(y) - x = 0` (each Newton step roughly squares the accuracy, so the
/// result is correct to ~1 ulp everywhere the tests probe).
pub fn erfinv(x: f64) -> f64 {
    if x.is_nan() || x <= -1.0 || x >= 1.0 {
        if x == 1.0 {
            return f64::INFINITY;
        }
        if x == -1.0 {
            return f64::NEG_INFINITY;
        }
        return f64::NAN;
    }
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let p = x.abs();
    // Safeguarded Newton on f(y) = erf(y) − p over the bracket [0, hi].
    // erf(6.5) is within 1 ulp of 1, so y* < 6.5 for any representable
    // p < 1. Newton from a crude log-based guess converges in ~5 steps;
    // bisection fallback guarantees convergence regardless.
    const TWO_OVER_SQRT_PI: f64 = 1.128_379_167_095_512_6;
    let (mut lo, mut hi) = (0.0f64, 6.5f64);
    // crude initial guess: y ≈ √(−ln(1−p²)) tracks the true inverse well
    let mut y = (-(1.0 - p * p).ln()).sqrt().min(6.0);
    for _ in 0..80 {
        let f = erf(y) - p;
        if f > 0.0 {
            hi = y;
        } else {
            lo = y;
        }
        let dfdy = TWO_OVER_SQRT_PI * (-y * y).exp();
        let step = f / dfdy;
        let mut next = y - step;
        if !(next > lo && next < hi) || !next.is_finite() {
            next = 0.5 * (lo + hi); // bisect when Newton leaves the bracket
        }
        if (next - y).abs() <= 1e-16 * y.abs().max(1e-16) {
            y = next;
            break;
        }
        y = next;
    }
    sign * y
}

/// Natural log of the Gamma function (Lanczos, g=7, n=9), |rel err| < 1e-13.
pub fn lgamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let s = (std::f64::consts::PI * x).sin();
        return std::f64::consts::PI.ln() - s.abs().ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from mpmath (50 digits, rounded).
    const ERF_TABLE: &[(f64, f64)] = &[
        (0.0, 0.0),
        (0.1, 0.112_462_916_018_284_9),
        (0.5, 0.520_499_877_813_046_5),
        (1.0, 0.842_700_792_949_714_9),
        (1.5, 0.966_105_146_475_310_7),
        (2.0, 0.995_322_265_018_952_7),
        (3.0, 0.999_977_909_503_001_4),
        (-0.7, -0.677_801_193_837_418_5),
    ];

    #[test]
    fn erf_table_values() {
        for &(x, want) in ERF_TABLE {
            let got = erf(x);
            assert!(
                (got - want).abs() < 2e-15,
                "erf({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn erfc_large_arguments() {
        // erfc(5) = 1.5374597944280348502e-12 (mpmath)
        let got = erfc(5.0);
        let want = 1.537_459_794_428_034_9e-12;
        assert!((got / want - 1.0).abs() < 1e-10, "erfc(5) = {got}");
        // erfc(10) = 2.0884875837625447570e-45
        let got = erfc(10.0);
        let want = 2.088_487_583_762_544_8e-45;
        assert!((got / want - 1.0).abs() < 1e-9, "erfc(10) = {got}");
        // symmetry erfc(-x) = 2 - erfc(x)
        assert!((erfc(-1.3) - (2.0 - erfc(1.3))).abs() < 1e-15);
    }

    #[test]
    fn erf_erfc_consistency() {
        for i in 0..200 {
            let x = -4.0 + 8.0 * (i as f64) / 199.0;
            assert!(
                (erf(x) + erfc(x) - 1.0).abs() < 3e-15,
                "erf+erfc != 1 at {x}"
            );
        }
    }

    #[test]
    fn erfinv_roundtrip() {
        for i in 1..999 {
            let p = -0.999 + 1.998 * (i as f64) / 998.0;
            let y = erfinv(p);
            let back = erf(y);
            assert!(
                (back - p).abs() < 1e-13,
                "erf(erfinv({p})) = {back}"
            );
        }
    }

    #[test]
    fn erfinv_known_values() {
        // erfinv(0.5) = 0.47693627620446987338 (mpmath)
        assert!((erfinv(0.5) - 0.476_936_276_204_469_87).abs() < 1e-13);
        // erfinv(0.99) = 1.8213863677184496
        assert!((erfinv(0.99) - 1.821_386_367_718_449_5).abs() < 1e-12);
        assert_eq!(erfinv(0.0), 0.0);
        assert!(erfinv(1.0).is_infinite());
    }

    #[test]
    fn lgamma_table() {
        // (x, ln Γ(x)) reference values
        let table = [
            (0.5, 0.572_364_942_924_700_1),   // ln √π
            (1.0, 0.0),
            (2.0, 0.0),
            (3.0, 2f64.ln()),
            (10.0, 12.801_827_480_081_469),
            (150.0, 600.009_470_555_327_4),
            (0.1, 2.252_712_651_734_206),
        ];
        for (x, want) in table {
            let got = lgamma(x);
            assert!(
                (got - want).abs() < 1e-11 * want.abs().max(1.0),
                "lgamma({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn lgamma_recurrence() {
        // Γ(x+1) = x Γ(x) → lgamma(x+1) = ln x + lgamma(x)
        for i in 1..50 {
            let x = 0.3 + i as f64 * 0.7;
            let lhs = lgamma(x + 1.0);
            let rhs = x.ln() + lgamma(x);
            assert!((lhs - rhs).abs() < 1e-11 * lhs.abs().max(1.0), "recurrence fails at {x}");
        }
    }
}
