//! Scalar special functions used throughout the crate.
//!
//! Everything here is self-contained (no external crates are available in
//! the build environment), double precision, and validated against
//! high-precision reference values in the unit tests.

mod special;

pub use special::{erf, erfc, erfinv, lgamma};

/// `ln(2π)` to full double precision.
pub const LN_2PI: f64 = 1.837_877_066_409_345_4;

/// `ln(2πe)` — appears in the profiled hyperlikelihood, eq. (2.16).
pub const LN_2PI_E: f64 = 2.837_877_066_409_345_4;

/// Numerically stable `ln(exp(a) + exp(b))`.
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Numerically stable `ln(exp(a) - exp(b))`, requires `a >= b`.
pub fn log_sub_exp(a: f64, b: f64) -> f64 {
    debug_assert!(a >= b, "log_sub_exp requires a >= b, got {a} < {b}");
    if b == f64::NEG_INFINITY {
        return a;
    }
    a + (-(b - a).exp()).ln_1p()
}

/// Stable log-sum-exp over a slice.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Relative difference `|a-b| / max(|a|, |b|, 1)` — the comparison metric
/// used by the finite-difference derivative tests.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_2pi_matches() {
        assert!((LN_2PI - (2.0 * std::f64::consts::PI).ln()).abs() < 1e-15);
        assert!((LN_2PI_E - (2.0 * std::f64::consts::PI * std::f64::consts::E).ln()).abs() < 1e-14);
    }

    #[test]
    fn log_add_exp_basic() {
        let a = 700.0;
        let b = 700.0;
        // naive exp(700) overflows; stable version does not
        assert!((log_add_exp(a, b) - (700.0 + 2f64.ln())).abs() < 1e-12);
        assert_eq!(log_add_exp(f64::NEG_INFINITY, 3.0), 3.0);
        assert_eq!(log_add_exp(3.0, f64::NEG_INFINITY), 3.0);
    }

    #[test]
    fn log_sub_exp_basic() {
        // ln(e^2 - e^1)
        let want = (2f64.exp() - 1f64.exp()).ln();
        assert!((log_sub_exp(2.0, 1.0) - want).abs() < 1e-12);
        assert_eq!(log_sub_exp(5.0, f64::NEG_INFINITY), 5.0);
    }

    #[test]
    fn log_sum_exp_basic() {
        let xs = [0.0, 0.0, 0.0, 0.0];
        assert!((log_sum_exp(&xs) - 4f64.ln()).abs() < 1e-12);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
        // mixed magnitudes
        let xs = [-1000.0, 0.0];
        assert!((log_sum_exp(&xs) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_basic() {
        assert_eq!(rel_diff(1.0, 1.0), 0.0);
        assert!((rel_diff(2.0, 1.0) - 0.5).abs() < 1e-15);
        // small numbers measured against 1
        assert!((rel_diff(1e-20, 0.0) - 1e-20).abs() < 1e-30);
    }
}
