//! Nested-sampling baseline — the stand-in for the paper's MULTINEST
//! comparator (Feroz & Hobson 2008/2009; Skilling 2006).
//!
//! Implements the standard nested-sampling evidence estimator with
//! bounding-ellipsoid likelihood-constrained proposals:
//!
//! * `nlive` live points in the unit hypercube (the prior transform is the
//!   caller's — see [`crate::priors::BoxPrior::from_unit_cube`]);
//! * at step k the worst point (ln L*) is replaced by a draw with
//!   `ln L > ln L*` sampled inside the enlarged bounding ellipsoid of the
//!   live set (MULTINEST's core idea, single-ellipsoid variant);
//! * prior-volume shrinkage `ln X_k = −k/nlive`, trapezoidal weights,
//!   `Z = Σ L_i w_i` accumulated in log space;
//! * termination when the maximum possible remaining contribution
//!   `L_max · X_k` falls below `tol · Z`;
//! * the information integral gives the classic evidence error estimate
//!   `σ(ln Z) ≈ √(H/nlive)`.
//!
//! The evaluation counter is the paper's headline cost metric: Table 1's
//! `ln Z_num` took "between 20,000 and 50,000 likelihood evaluations".

use crate::linalg::{sym_eigen, Chol, Matrix};
use crate::math::{log_add_exp, log_sub_exp};
use crate::rng::Xoshiro256;

/// Options for a nested-sampling run.
#[derive(Clone, Copy, Debug)]
pub struct NestedOptions {
    /// Number of live points (MULTINEST default era: 400–1000).
    pub nlive: usize,
    /// Termination tolerance on the remaining-evidence fraction.
    pub tol: f64,
    /// Ellipsoid enlargement factor (>1).
    pub enlarge: f64,
    /// Hard cap on iterations (safety).
    pub max_iters: usize,
}

impl Default for NestedOptions {
    fn default() -> Self {
        Self { nlive: 400, tol: 1e-3, enlarge: 1.15, max_iters: 200_000 }
    }
}

/// One weighted posterior sample from the run.
#[derive(Clone, Debug)]
pub struct WeightedSample {
    /// Unit-cube coordinates.
    pub u: Vec<f64>,
    /// ln likelihood.
    pub ln_l: f64,
    /// ln posterior weight (normalised: logsumexp over samples = 0).
    pub ln_w: f64,
}

/// Result of a nested-sampling run.
#[derive(Debug)]
pub struct NestedResult {
    /// ln Z estimate.
    pub ln_z: f64,
    /// Error estimate σ(ln Z) = √(H/nlive).
    pub ln_z_err: f64,
    /// Information (KL divergence prior→posterior), nats.
    pub information: f64,
    /// Total likelihood evaluations — the paper's cost metric.
    pub n_evals: usize,
    /// Iterations (dead points).
    pub n_iters: usize,
    /// Weighted posterior samples (dead + final live points).
    pub samples: Vec<WeightedSample>,
}

/// Run nested sampling over the unit hypercube.
///
/// `ln_like(u)` must return `f64::NEG_INFINITY` (or any non-finite value)
/// for invalid points; those count as zero-likelihood prior volume.
pub fn nested_sample<F>(
    dim: usize,
    mut ln_like: F,
    opts: &NestedOptions,
    rng: &mut Xoshiro256,
) -> crate::Result<NestedResult>
where
    F: FnMut(&[f64]) -> f64,
{
    anyhow::ensure!(opts.nlive >= dim + 2, "need nlive ≥ dim+2");
    let nlive = opts.nlive;
    let mut n_evals = 0usize;
    // initialise live set
    let mut live_u: Vec<Vec<f64>> = Vec::with_capacity(nlive);
    let mut live_l: Vec<f64> = Vec::with_capacity(nlive);
    for _ in 0..nlive {
        loop {
            let u: Vec<f64> = (0..dim).map(|_| rng.uniform()).collect();
            let l = ln_like(&u);
            n_evals += 1;
            if l.is_finite() {
                live_u.push(u);
                live_l.push(l);
                break;
            }
        }
    }

    let ln_shrink = -1.0 / nlive as f64; // E[ln t] per iteration
    let mut ln_x_prev = 0.0; // ln X_0 = 0
    let mut ln_z = f64::NEG_INFINITY;
    let mut info_acc = 0.0; // ∫ L/Z ln(L/Z) dX accumulated incrementally
    let mut samples: Vec<WeightedSample> = Vec::new();
    let mut n_iters = 0usize;

    while n_iters < opts.max_iters {
        n_iters += 1;
        // worst live point
        let (worst, &ln_l_star) = live_l
            .iter()
            .enumerate()
            .min_by(|a, b| crate::util::asc_nan_last(*a.1, *b.1))
            .unwrap();
        let ln_x = ln_x_prev + ln_shrink;
        // trapezoid weight: w = X_{k-1} − X_k
        let ln_w = log_sub_exp(ln_x_prev, ln_x);
        let ln_zw = ln_l_star + ln_w;
        let ln_z_new = log_add_exp(ln_z, ln_zw);
        // incremental information update (Skilling's recurrence)
        if ln_zw.is_finite() {
            let z_ratio = (ln_z - ln_z_new).exp();
            let w_ratio = (ln_zw - ln_z_new).exp();
            info_acc = z_ratio * (info_acc + (ln_z - ln_z_new))
                + w_ratio * (ln_l_star - ln_z_new);
            // note: rearranged H-update; see tests for calibration
            info_acc = if info_acc.is_finite() { info_acc } else { 0.0 };
        }
        ln_z = ln_z_new;
        samples.push(WeightedSample { u: live_u[worst].clone(), ln_l: ln_l_star, ln_w: ln_zw });
        ln_x_prev = ln_x;

        // termination: remaining mass bound
        let ln_l_max = live_l.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        if ln_l_max + ln_x < ln_z + (opts.tol).ln() {
            break;
        }

        // replace the worst point with an ellipsoid draw above ln L*
        let (u_new, l_new, evals) =
            draw_above(&live_u, worst, ln_l_star, &mut ln_like, opts, rng, dim)?;
        n_evals += evals;
        live_u[worst] = u_new;
        live_l[worst] = l_new;
    }

    // final live-point contribution: each carries X_final/nlive
    let ln_w_live = ln_x_prev - (nlive as f64).ln();
    for (u, &l) in live_u.iter().zip(&live_l) {
        let ln_zw = l + ln_w_live;
        let ln_z_new = log_add_exp(ln_z, ln_zw);
        let z_ratio = (ln_z - ln_z_new).exp();
        let w_ratio = (ln_zw - ln_z_new).exp();
        info_acc = z_ratio * (info_acc + (ln_z - ln_z_new)) + w_ratio * (l - ln_z_new);
        ln_z = ln_z_new;
        samples.push(WeightedSample { u: u.clone(), ln_l: l, ln_w: ln_zw });
    }

    // normalise weights to logsumexp = 0
    for s in &mut samples {
        s.ln_w -= ln_z;
    }
    // information H = Σ w (lnL − lnZ) over normalised weights
    let information: f64 = samples
        .iter()
        .map(|s| {
            let w = s.ln_w.exp();
            if w > 0.0 {
                w * (s.ln_l - ln_z)
            } else {
                0.0
            }
        })
        .sum();
    let ln_z_err = (information.max(0.0) / nlive as f64).sqrt();
    Ok(NestedResult { ln_z, ln_z_err, information, n_evals, n_iters, samples })
}

/// Draw a unit-cube point with `ln L > ln L*` from the enlarged bounding
/// ellipsoid of the live set (excluding `skip`, the point being replaced —
/// standard practice so the ellipsoid is not inflated by the worst point).
#[allow(clippy::too_many_arguments)]
fn draw_above<F>(
    live: &[Vec<f64>],
    skip: usize,
    ln_l_star: f64,
    ln_like: &mut F,
    opts: &NestedOptions,
    rng: &mut Xoshiro256,
    dim: usize,
) -> crate::Result<(Vec<f64>, f64, usize)>
where
    F: FnMut(&[f64]) -> f64,
{
    // mean and covariance of the live set
    let n = live.len();
    let mut mean = vec![0.0; dim];
    for (i, u) in live.iter().enumerate() {
        if i == skip {
            continue;
        }
        for d in 0..dim {
            mean[d] += u[d];
        }
    }
    for v in &mut mean {
        *v /= (n - 1) as f64;
    }
    let mut cov = Matrix::zeros(dim, dim);
    for (i, u) in live.iter().enumerate() {
        if i == skip {
            continue;
        }
        for a in 0..dim {
            for b in 0..dim {
                cov[(a, b)] += (u[a] - mean[a]) * (u[b] - mean[b]);
            }
        }
    }
    for v in cov.as_mut_slice() {
        *v /= (n - 2).max(1) as f64;
    }
    // jitter for degenerate directions
    for d in 0..dim {
        cov[(d, d)] += 1e-12;
    }
    // max Mahalanobis distance of live points = ellipsoid scale
    let chol = Chol::factor(&cov).map_err(|e| anyhow::anyhow!("live-set covariance: {e}"))?;
    let mut scale2 = 0.0f64;
    let mut diff = vec![0.0; dim];
    for (i, u) in live.iter().enumerate() {
        if i == skip {
            continue;
        }
        for d in 0..dim {
            diff[d] = u[d] - mean[d];
        }
        scale2 = scale2.max(chol.inv_quad(&diff));
    }
    let scale = scale2.sqrt() * opts.enlarge;
    // principal axes for sampling
    let (evals, evecs) = sym_eigen(&cov);
    let mut attempts = 0usize;
    let mut enlarge_extra = 1.0;
    let mut evals_used = 0usize;
    loop {
        attempts += 1;
        if attempts % 500 == 0 {
            enlarge_extra *= 1.5; // widen if the constrained region is awkward
        }
        if attempts >= 20_000 {
            // Ellipsoid proposals are failing (typically: a degenerate live
            // set hugging a cube face). Fall back to a likelihood-constrained
            // random walk from a random live point — always succeeds because
            // live points themselves satisfy the constraint.
            return mcmc_above(live, skip, ln_l_star, ln_like, rng, dim)
                .map(|(u, l, e)| (u, l, evals_used + e));
        }
        // uniform in unit ball: normal direction, radius^(1/dim)
        let mut z = vec![0.0; dim];
        rng.fill_normal(&mut z);
        let norm = crate::linalg::norm2(&z).max(1e-300);
        let r = rng.uniform().powf(1.0 / dim as f64);
        let factor = r / norm * scale * enlarge_extra;
        // x = mean + V diag(√λ) z·factor
        let mut x = mean.clone();
        let mut ok = true;
        for a in 0..dim {
            let mut acc = 0.0;
            for b in 0..dim {
                acc += evecs[(a, b)] * evals[b].max(0.0).sqrt() * z[b];
            }
            x[a] += acc * factor;
            if !(0.0..=1.0).contains(&x[a]) {
                ok = false;
                break;
            }
        }
        if !ok {
            continue; // outside the unit cube: reject without an eval
        }
        let l = ln_like(&x);
        evals_used += 1;
        if l.is_finite() && l > ln_l_star {
            return Ok((x, l, evals_used));
        }
    }
}

/// Likelihood-constrained random-walk fallback: start from a random live
/// point (which satisfies `ln L > ln L*` by construction) and take
/// Gaussian steps, accepting any in-cube point above the threshold.
/// Step size adapts down on rejection; a fixed walk length decorrelates
/// the sample from its seed point.
fn mcmc_above<F>(
    live: &[Vec<f64>],
    skip: usize,
    ln_l_star: f64,
    ln_like: &mut F,
    rng: &mut Xoshiro256,
    _dim: usize,
) -> crate::Result<(Vec<f64>, f64, usize)>
where
    F: FnMut(&[f64]) -> f64,
{
    // seed from a random live point other than the one being replaced
    let seed_idx = loop {
        let i = rng.below(live.len());
        if i != skip || live.len() == 1 {
            break i;
        }
    };
    let mut x = live[seed_idx].clone();
    let mut l_cur = ln_like(&x);
    let mut evals = 1usize;
    if !(l_cur.is_finite() && l_cur > ln_l_star) {
        // numerical edge: re-evaluate gave a boundary value; nudge later
        l_cur = f64::NEG_INFINITY;
    }
    let mut step = 0.05;
    let mut accepted = 0usize;
    const WALK: usize = 40;
    for _ in 0..20_000 {
        if accepted >= WALK {
            break;
        }
        let mut prop = x.clone();
        for v in prop.iter_mut() {
            *v += step * rng.normal();
        }
        if prop.iter().any(|v| !(0.0..=1.0).contains(v)) {
            step *= 0.95;
            continue;
        }
        let l = ln_like(&prop);
        evals += 1;
        if l.is_finite() && l > ln_l_star {
            x = prop;
            l_cur = l;
            accepted += 1;
            step *= 1.05;
        } else {
            step *= 0.95;
        }
        step = step.clamp(1e-7, 0.5);
    }
    anyhow::ensure!(
        l_cur.is_finite() && l_cur > ln_l_star && accepted > 0,
        "likelihood-constrained walk failed to move above the threshold"
    );
    Ok((x, l_cur, evals))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Gaussian likelihood over a flat unit-cube prior — analytic Z.
    /// L(u) = N(u; 0.5, σ² I) ⇒ Z ≈ 1 (σ ≪ 1 keeps all mass inside).
    fn gaussian_lnlike(sigma: f64) -> impl FnMut(&[f64]) -> f64 {
        move |u: &[f64]| {
            let mut q = 0.0;
            for &ui in u {
                let d = (ui - 0.5) / sigma;
                q += d * d;
            }
            -0.5 * q - u.len() as f64 * (sigma.ln() + 0.5 * crate::math::LN_2PI)
        }
    }

    #[test]
    fn recovers_gaussian_evidence_2d() {
        let mut rng = Xoshiro256::seed_from_u64(12);
        let res = nested_sample(
            2,
            gaussian_lnlike(0.05),
            &NestedOptions { nlive: 300, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        // true ln Z = 0 (normalised Gaussian wholly inside the cube)
        assert!(
            res.ln_z.abs() < 3.0 * res.ln_z_err.max(0.02),
            "lnZ = {} ± {}",
            res.ln_z,
            res.ln_z_err
        );
        assert!(res.ln_z_err < 0.2);
        assert!(res.n_evals > res.n_iters);
    }

    #[test]
    fn recovers_scaled_evidence() {
        // L = const · N ⇒ ln Z = ln const
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut base = gaussian_lnlike(0.07);
        let res = nested_sample(
            2,
            move |u: &[f64]| base(u) + 7.5,
            &NestedOptions { nlive: 300, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        assert!(
            (res.ln_z - 7.5).abs() < 3.0 * res.ln_z_err.max(0.02),
            "lnZ = {} ± {}",
            res.ln_z,
            res.ln_z_err
        );
    }

    #[test]
    fn information_positive_and_sensible() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let res = nested_sample(
            2,
            gaussian_lnlike(0.05),
            &NestedOptions { nlive: 250, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        // H ≈ ln(prior vol / posterior vol) ≈ 2·ln(1/(σ√(2πe))) ≈ 3.6
        assert!(res.information > 1.0 && res.information < 8.0, "H = {}", res.information);
    }

    #[test]
    fn weights_normalised() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let res = nested_sample(
            1,
            gaussian_lnlike(0.1),
            &NestedOptions { nlive: 150, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        let total: f64 = res.samples.iter().map(|s| s.ln_w.exp()).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σw = {total}");
    }

    #[test]
    fn posterior_mean_matches_truth() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let res = nested_sample(
            2,
            gaussian_lnlike(0.08),
            &NestedOptions { nlive: 300, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        for d in 0..2 {
            let mean: f64 = res.samples.iter().map(|s| s.ln_w.exp() * s.u[d]).sum();
            assert!((mean - 0.5).abs() < 0.01, "dim {d} mean {mean}");
            let var: f64 = res
                .samples
                .iter()
                .map(|s| s.ln_w.exp() * (s.u[d] - mean) * (s.u[d] - mean))
                .sum();
            assert!((var.sqrt() - 0.08).abs() < 0.02, "dim {d} sd {}", var.sqrt());
        }
    }

    #[test]
    fn handles_invalid_regions() {
        // likelihood undefined (−∞) on half the cube — sampler must avoid it
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut g = gaussian_lnlike(0.05);
        let res = nested_sample(
            2,
            move |u: &[f64]| if u[0] > 0.9 { f64::NEG_INFINITY } else { g(u) },
            &NestedOptions { nlive: 200, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        // truncation removes ~0 mass; allow ~3.5σ of sampler noise
        assert!(
            res.ln_z.abs() < 3.5 * res.ln_z_err.max(0.05),
            "lnZ = {} ± {}",
            res.ln_z,
            res.ln_z_err
        );
    }
}
