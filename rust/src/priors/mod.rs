//! Hyperprior system: the paper's flat-prior reparametrisations (§3) and
//! the unit-hypercube mapping used by the nested sampler and the Laplace
//! volume bookkeeping.
//!
//! Every hyperparameter is carried in a **flat coordinate** (φ for
//! Jeffreys-prior timescales, eq. 3.4; ξ for log-normal smoothness
//! parameters, eq. 3.5; λ = ln σ_f for the Jeffreys scale prior). The
//! prior over the flat coordinates is uniform on a box, so:
//!
//! * the hyperposterior ∝ hyperlikelihood (the assumption behind
//!   eq. 2.13),
//! * the prior volume `V` is the box volume (the Occam factor of §2(a)),
//! * a unit-cube point `u ∈ [0,1]^m` maps affinely to the box — which is
//!   exactly the prior transform MULTINEST-style samplers need.

use crate::kernels::{CovarianceModel, DataSpan};

/// The box prior over a model's reduced hyperparameters ϑ, with optional
/// ordering constraints (the paper's `T₂ ≥ T₁`).
#[derive(Clone, Debug)]
pub struct BoxPrior {
    /// Per-coordinate (lo, hi).
    pub bounds: Vec<(f64, f64)>,
    /// Pairs (i, j) requiring `θ[i] ≤ θ[j]`.
    pub constraints: Vec<(usize, usize)>,
}

impl BoxPrior {
    /// Build from a model and the data geometry.
    pub fn for_model(model: &CovarianceModel, span: &DataSpan) -> Self {
        Self {
            bounds: model.kernel.bounds(span),
            constraints: model.kernel.ordering_constraints(),
        }
    }

    pub fn dim(&self) -> usize {
        self.bounds.len()
    }

    /// Is θ inside the box with all constraints satisfied?
    pub fn contains(&self, theta: &[f64]) -> bool {
        theta.len() == self.dim()
            && theta
                .iter()
                .zip(&self.bounds)
                .all(|(v, (lo, hi))| *v >= *lo && *v <= *hi)
            && self.constraints.iter().all(|&(i, j)| theta[i] <= theta[j])
    }

    /// Clamp θ into the box (used by the bounded optimiser); ordering
    /// constraints are restored by collapsing offending pairs to their
    /// midpoint.
    pub fn project(&self, theta: &mut [f64]) {
        for (v, (lo, hi)) in theta.iter_mut().zip(&self.bounds) {
            *v = v.clamp(*lo, *hi);
        }
        for &(i, j) in &self.constraints {
            if theta[i] > theta[j] {
                let mid = 0.5 * (theta[i] + theta[j]);
                theta[i] = mid;
                theta[j] = mid;
            }
        }
    }

    /// Map a unit-cube point to the box, honouring ordering constraints by
    /// conditional stretching: a constrained coordinate `j` (θ_i ≤ θ_j) is
    /// mapped into `[θ_i, hi_j]` — the paper's conditional flat prior on
    /// `T₂ ∈ (T₁, ΔT)`.
    pub fn from_unit_cube(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.dim());
        let mut theta: Vec<f64> = u
            .iter()
            .zip(&self.bounds)
            .map(|(ui, (lo, hi))| lo + ui * (hi - lo))
            .collect();
        for &(i, j) in &self.constraints {
            let (_, hi_j) = self.bounds[j];
            theta[j] = theta[i] + u[j] * (hi_j - theta[i]).max(0.0);
        }
        theta
    }

    /// Natural log of the prior volume **at a point**: the product of
    /// coordinate ranges, with each constrained coordinate contributing its
    /// conditional range `(θ_i, hi_j)` instead of the full one. This is the
    /// `V` of eq. (2.13) as realised by [`Self::from_unit_cube`].
    pub fn ln_volume_at(&self, theta: &[f64]) -> f64 {
        let mut v = 0.0;
        for (idx, (lo, hi)) in self.bounds.iter().enumerate() {
            if let Some(&(i, _)) = self.constraints.iter().find(|&&(_, j)| j == idx) {
                v += (hi - theta[i]).max(f64::MIN_POSITIVE).ln();
            } else {
                v += (hi - lo).ln();
            }
        }
        v
    }

    /// Draw a uniform point from the prior.
    pub fn sample(&self, rng: &mut crate::rng::Xoshiro256) -> Vec<f64> {
        let u: Vec<f64> = (0..self.dim()).map(|_| rng.uniform()).collect();
        self.from_unit_cube(&u)
    }
}

/// The σ_f scale prior: truncated Jeffreys `P(σ_f) ∝ 1/σ_f` on
/// `(σ_lo, σ_hi)`, i.e. flat in `λ = ln σ_f`.
#[derive(Clone, Copy, Debug)]
pub struct ScalePrior {
    pub sigma_lo: f64,
    pub sigma_hi: f64,
}

impl Default for ScalePrior {
    /// A deliberately generous default range; the paper fixes "suitable
    /// prior volumes" without stating them — Bayes factors are insensitive
    /// because the σ_f range cancels between models on the same data.
    fn default() -> Self {
        Self { sigma_lo: 1e-3, sigma_hi: 1e3 }
    }
}

impl ScalePrior {
    /// λ-range (flat coordinate).
    pub fn lambda_bounds(&self) -> (f64, f64) {
        (self.sigma_lo.ln(), self.sigma_hi.ln())
    }

    /// ln of the λ volume: `ln ln(σ_hi/σ_lo)`.
    pub fn ln_volume(&self) -> f64 {
        (self.sigma_hi / self.sigma_lo).ln().ln()
    }

    /// Map u ∈ [0,1] to λ.
    pub fn lambda_from_unit(&self, u: f64) -> f64 {
        let (lo, hi) = self.lambda_bounds();
        lo + u * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::paper_k2;
    use crate::rng::Xoshiro256;

    fn k2_prior() -> BoxPrior {
        let m = paper_k2(0.1);
        let span = DataSpan { dt_min: 1.0, dt_max: 100.0 };
        BoxPrior::for_model(&m, &span)
    }

    #[test]
    fn cube_mapping_hits_box_and_constraints() {
        let p = k2_prior();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..500 {
            let u: Vec<f64> = (0..p.dim()).map(|_| rng.uniform()).collect();
            let theta = p.from_unit_cube(&u);
            assert!(p.contains(&theta), "mapped point must satisfy prior: {theta:?}");
        }
    }

    #[test]
    fn cube_corners() {
        let p = k2_prior();
        let lo = p.from_unit_cube(&vec![0.0; 5]);
        // at u=0 every coordinate sits at its lower bound (constrained φ2
        // degenerates to φ1 = its own lower bound here, which coincides)
        for (v, (l, _)) in lo.iter().zip(&p.bounds) {
            assert!((v - l).abs() < 1e-12);
        }
        let hi = p.from_unit_cube(&vec![1.0; 5]);
        for (idx, (v, (_, h))) in hi.iter().zip(&p.bounds).enumerate() {
            assert!((v - h).abs() < 1e-9, "coord {idx}: {v} vs {h}");
        }
    }

    #[test]
    fn project_restores_feasibility() {
        let p = k2_prior();
        // violate box and constraint: φ1 > φ2
        let mut theta = vec![200.0, 4.0, 0.9, 1.0, -0.9];
        p.project(&mut theta);
        assert!(p.contains(&theta), "{theta:?}");
    }

    #[test]
    fn volume_at_unconstrained_matches_product() {
        let m = crate::kernels::paper_k1(0.1);
        let span = DataSpan { dt_min: 1.0, dt_max: 100.0 };
        let p = BoxPrior::for_model(&m, &span);
        let theta = p.from_unit_cube(&[0.5, 0.5, 0.5]);
        let direct: f64 = p.bounds.iter().map(|(lo, hi)| (hi - lo).ln()).sum();
        assert!((p.ln_volume_at(&theta) - direct).abs() < 1e-12);
    }

    #[test]
    fn volume_at_constrained_uses_conditional_range() {
        let p = k2_prior();
        let theta = p.from_unit_cube(&[0.5, 0.5, 0.5, 0.5, 0.5]);
        let mut want = 0.0;
        for (idx, (lo, hi)) in p.bounds.iter().enumerate() {
            if idx == 3 {
                want += (hi - theta[1]).ln(); // conditional on φ1
            } else {
                want += (hi - lo).ln();
            }
        }
        assert!((p.ln_volume_at(&theta) - want).abs() < 1e-12);
    }

    #[test]
    fn sample_respects_prior() {
        let p = k2_prior();
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..200 {
            let theta = p.sample(&mut rng);
            assert!(p.contains(&theta));
        }
    }

    #[test]
    fn scale_prior_volume() {
        let s = ScalePrior { sigma_lo: 0.1, sigma_hi: 10.0 };
        assert!((s.ln_volume() - (100f64.ln()).ln()).abs() < 1e-12);
        assert!((s.lambda_from_unit(0.0) - 0.1f64.ln()).abs() < 1e-12);
        assert!((s.lambda_from_unit(1.0) - 10f64.ln()).abs() < 1e-12);
    }
}
