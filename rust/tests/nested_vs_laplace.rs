//! Focused consistency test between the two evidence estimators on a
//! *known* integrand: a synthetic log-likelihood whose evidence has a
//! closed form. This isolates the estimator math from GP specifics —
//! if both machines integrate a known Gaussian correctly, Table-1 level
//! disagreements must come from non-Gaussianity of the posterior, which
//! is exactly the paper's interpretation of its (k₂, n=30) outlier.

use gpfast::evidence::laplace_evidence;
use gpfast::linalg::Matrix;
use gpfast::nested::{nested_sample, NestedOptions};
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;

/// A 3-D Gaussian "hyperlikelihood" over a box prior, with analytic Z.
struct Toy {
    prior: BoxPrior,
    peak: Vec<f64>,
    hess: Matrix,
    ln_p_peak: f64,
}

impl Toy {
    fn new() -> Self {
        Self {
            prior: BoxPrior { bounds: vec![(-8.0, 8.0); 3], constraints: vec![] },
            peak: vec![0.5, -1.0, 2.0],
            hess: Matrix::from_rows(&[
                &[4.0, 0.5, 0.0],
                &[0.5, 9.0, 1.0],
                &[0.0, 1.0, 2.0],
            ]),
            ln_p_peak: -4.0,
        }
    }

    fn ln_p(&self, theta: &[f64]) -> f64 {
        let d: Vec<f64> = theta.iter().zip(&self.peak).map(|(a, b)| a - b).collect();
        let hd = self.hess.matvec(&d);
        self.ln_p_peak - 0.5 * gpfast::linalg::dot(&d, &hd)
    }
}

#[test]
fn both_estimators_agree_on_gaussian_integrand() {
    let toy = Toy::new();
    // Laplace: exact for this integrand (modulo box truncation ~0).
    // Use a σ_f prior with zero extra dimension by noting laplace_evidence
    // adds the marg constant: replicate it in the nested integrand instead.
    let scale = ScalePrior::default();
    let n_data = 10; // arbitrary: contributes the same constant to both
    let lap = laplace_evidence(n_data, &toy.prior, &scale, &toy.peak, toy.ln_p_peak, &toy.hess)
        .unwrap();
    assert!(!lap.suspect);

    // Nested: integrate the same thing — P_max over ϑ-cube; add the same
    // marginalisation constant afterwards.
    let mut rng = Xoshiro256::seed_from_u64(11);
    let res = nested_sample(
        3,
        |u: &[f64]| toy.ln_p(&toy.prior.from_unit_cube(u)),
        &NestedOptions { nlive: 400, ..Default::default() },
        &mut rng,
    )
    .unwrap();
    let marg = gpfast::gp::marg_constant(n_data, scale.sigma_lo, scale.sigma_hi);
    let ln_z_nested = res.ln_z + marg;
    let tol = 3.5 * res.ln_z_err.max(0.05);
    assert!(
        (lap.ln_z - ln_z_nested).abs() < tol,
        "laplace {} vs nested {} ± {}",
        lap.ln_z,
        ln_z_nested,
        res.ln_z_err
    );
}

#[test]
fn laplace_error_bars_match_gaussian_truth() {
    let toy = Toy::new();
    let lap = laplace_evidence(
        10,
        &toy.prior,
        &ScalePrior::default(),
        &toy.peak,
        toy.ln_p_peak,
        &toy.hess,
    )
    .unwrap();
    // σ_i = sqrt((H⁻¹)_ii)
    let hinv = gpfast::linalg::Lu::factor(&toy.hess).unwrap().inverse();
    for i in 0..3 {
        assert!((lap.sigma[i] - hinv[(i, i)].sqrt()).abs() < 1e-12);
    }
}

#[test]
fn nested_posterior_moments_match_gaussian() {
    let toy = Toy::new();
    let mut rng = Xoshiro256::seed_from_u64(13);
    let res = nested_sample(
        3,
        |u: &[f64]| toy.ln_p(&toy.prior.from_unit_cube(u)),
        &NestedOptions { nlive: 400, ..Default::default() },
        &mut rng,
    )
    .unwrap();
    // posterior mean ≈ peak (Gaussian, box-interior)
    for d in 0..3 {
        let mean: f64 = res
            .samples
            .iter()
            .map(|s| s.ln_w.exp() * toy.prior.from_unit_cube(&s.u)[d])
            .sum();
        assert!(
            (mean - toy.peak[d]).abs() < 0.1,
            "dim {d}: posterior mean {mean} vs peak {}",
            toy.peak[d]
        );
    }
}
