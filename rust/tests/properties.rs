//! Cross-module property tests (the `propcheck` mini-framework):
//! invariants that must hold for *any* valid inputs, not just the
//! example cases of the unit suites.

use gpfast::gp::profiled::ProfiledEval;
use gpfast::kernels::{paper_k1, paper_k2, DataSpan, PaperK1, PaperK2};
use gpfast::linalg::{Chol, Matrix, ToeplitzSolver};
use gpfast::priors::BoxPrior;
use gpfast::propcheck::{property, Gen};

/// Random irregular time grid.
fn gen_times(g: &mut Gen, max_n: usize) -> Vec<f64> {
    let n = g.usize(8..max_n);
    let mut t = Vec::with_capacity(n);
    let mut acc = 0.0;
    for _ in 0..n {
        acc += g.f64(0.2, 3.0);
        t.push(acc);
    }
    t
}

/// Random k2 hyperparameters inside the prior box of the grid.
fn gen_theta_k2(g: &mut Gen, span: &DataSpan) -> Vec<f64> {
    let (lo, hi) = span.phi_bounds();
    let phi0 = g.f64(lo + 0.3 * (hi - lo), hi);
    let phi1 = g.f64(lo, hi - 0.5);
    let phi2 = g.f64(phi1, hi); // respects T2 >= T1
    vec![phi0, phi1, g.f64(-0.4, 0.4), phi2, g.f64(-0.4, 0.4)]
}

#[test]
fn assembled_covariance_is_positive_definite() {
    property("K(θ) is PD for any prior-interior θ", 40, |g| {
        let t = gen_times(g, 40);
        let span = DataSpan::from_times(&t).unwrap();
        let theta = gen_theta_k2(g, &span);
        let model = paper_k2(g.f64(0.01, 0.3));
        let k = gpfast::gp::assemble_cov(&model, &t, &theta);
        match Chol::factor(&k) {
            Ok(_) => Ok(()),
            Err(e) => Err(format!("not PD at θ={theta:?}: {e}")),
        }
    });
}

#[test]
fn profiled_sigma_hat_is_scale_equivariant() {
    // scaling y by c scales σ̂_f² by c² and shifts lnP by −n ln c
    property("σ̂_f²(c·y) = c²σ̂_f²(y)", 30, |g| {
        let t = gen_times(g, 30);
        let span = DataSpan::from_times(&t).unwrap();
        let theta = gen_theta_k2(g, &span);
        let model = paper_k2(0.1);
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.7).sin() + 0.3 * (x * 0.13).cos()).collect();
        let c = g.f64(0.5, 3.0);
        let yc: Vec<f64> = y.iter().map(|v| c * v).collect();
        let k = gpfast::gp::assemble_cov(&model, &t, &theta);
        let e1 = ProfiledEval::from_cov(k.clone(), &y).map_err(|e| e.to_string())?;
        let e2 = ProfiledEval::from_cov(k, &yc).map_err(|e| e.to_string())?;
        let want = c * c * e1.sigma_f_hat2;
        if (e2.sigma_f_hat2 - want).abs() > 1e-9 * want {
            return Err(format!("{} vs {want}", e2.sigma_f_hat2));
        }
        let n = y.len() as f64;
        let want_lnp = e1.lnp - n * c.ln();
        if (e2.lnp - want_lnp).abs() > 1e-8 * want_lnp.abs() {
            return Err(format!("lnp {} vs {want_lnp}", e2.lnp));
        }
        Ok(())
    });
}

#[test]
fn profiled_lnp_is_maximum_over_explicit_sigma() {
    // for random λ, full_lnp([λ, ϑ]) ≤ lnP_max(ϑ)
    property("lnP(λ, ϑ) ≤ lnP_max(ϑ)", 25, |g| {
        let t = gen_times(g, 25);
        let span = DataSpan::from_times(&t).unwrap();
        let theta = gen_theta_k2(g, &span);
        let model = paper_k2(0.1);
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.9).sin()).collect();
        let ev = gpfast::gp::profiled::eval(&model, &t, &y, &theta).map_err(|e| e.to_string())?;
        let lambda = g.f64(-2.0, 2.0);
        let mut full = vec![lambda];
        full.extend(theta.iter().copied());
        let lnp = gpfast::gp::full_lnp(&model, &t, &y, &full).map_err(|e| e.to_string())?;
        if lnp <= ev.lnp + 1e-9 * ev.lnp.abs().max(1.0) {
            Ok(())
        } else {
            Err(format!("full {lnp} exceeds profiled max {}", ev.lnp))
        }
    });
}

#[test]
fn toeplitz_matches_cholesky_on_regular_grids() {
    property("Levinson solve == Cholesky solve on regular grids", 25, |g| {
        let n = g.usize(5..40);
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let span = DataSpan::from_times(&t).unwrap();
        let (lo, hi) = span.phi_bounds();
        let theta = vec![g.f64(lo + 0.5 * (hi - lo), hi), g.f64(lo, hi), g.f64(-0.3, 0.3)];
        // first column defines the Toeplitz operator on a regular grid
        let k = gpfast::gp::assemble_cov(&model, &t, &theta);
        let col: Vec<f64> = (0..n).map(|i| k[(i, 0)]).collect();
        let ts = ToeplitzSolver::new(&col).map_err(|e| e.to_string())?;
        let ch = Chol::factor(&k).map_err(|e| e.to_string())?;
        let b: Vec<f64> = (0..n).map(|i| ((i * i) as f64 * 0.11).sin()).collect();
        let xt = ts.solve(&b);
        let xc = ch.solve(&b);
        for i in 0..n {
            if (xt[i] - xc[i]).abs() > 1e-7 * xc[i].abs().max(1.0) {
                return Err(format!("n={n} i={i}: {} vs {}", xt[i], xc[i]));
            }
        }
        if (ts.logdet() - ch.logdet()).abs() > 1e-7 * ch.logdet().abs().max(1.0) {
            return Err(format!("logdet {} vs {}", ts.logdet(), ch.logdet()));
        }
        Ok(())
    });
}

#[test]
fn prior_cube_roundtrip_volume_consistency() {
    property("cube → θ stays in prior; volume finite", 100, |g| {
        let t = gen_times(g, 20);
        let span = DataSpan::from_times(&t).unwrap();
        let model = paper_k2(0.1);
        let prior = BoxPrior::for_model(&model, &span);
        let u: Vec<f64> = (0..prior.dim()).map(|_| g.f64(0.0, 1.0)).collect();
        let theta = prior.from_unit_cube(&u);
        if !prior.contains(&theta) {
            return Err(format!("mapped point escapes prior: {theta:?}"));
        }
        let v = prior.ln_volume_at(&theta);
        if !v.is_finite() {
            return Err(format!("non-finite volume at {theta:?}"));
        }
        Ok(())
    });
}

#[test]
#[ignore = "wall-clock heavy: k2 multistart (10 restarts) at n = 300 — minutes serial, \
            and tier-1 now runs twice (ci.sh serial+parallel passes). Statistical \
            recovery is a paper-validation check, not a regression gate; run \
            explicitly with `cargo test --release -- --ignored`. Tracked in \
            ROADMAP.md §Tier-1 test ledger."]
fn truth_parameters_recovered_within_error_bars_on_large_n() {
    // statistical sanity at n = 300, k2. The periodic hyperlikelihood is
    // genuinely multimodal (harmonic aliases — the phenomenon behind the
    // paper's flagged case), so the *guaranteed* invariant is that the
    // trained peak dominates the truth point: lnP(θ̂) ≥ lnP(θ_truth).
    // When multistart additionally lands in the truth's own mode, φ1 must
    // agree with the truth within ~5σ of the inverse-Hessian error bar
    // (the paper's T1 = 12.44 ± 0.07 h analogue).
    use gpfast::coordinator::{train_model, ModelSpec, TrainOptions};
    use gpfast::rng::Xoshiro256;
    use gpfast::runtime::ExecutionContext;
    let data = gpfast::data::synthetic::table1_dataset(300, 0.1, 99);
    let mut rng = Xoshiro256::seed_from_u64(17);
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 10;
    // help multistart with the truth's basin as one deterministic start —
    // the pipeline's warm-start mechanism in miniature
    opts.extra_starts = vec![vec![3.0, 1.2, 0.1, 2.8, 0.1]];
    let exec = ExecutionContext::from_env();
    let res = train_model(&ModelSpec::K2, 0.1, &data, &opts, 2, &exec, &mut rng).unwrap();
    let model = paper_k2(0.1);
    let truth = PaperK2::truth();
    let _ = PaperK1::truth();
    // invariant 1: the found peak dominates the truth point
    let ev_truth = gpfast::gp::profiled::eval(&model, &data.t, &data.y, &truth).unwrap();
    assert!(
        res.lnp_peak >= ev_truth.lnp - 1e-6,
        "trained peak {} below truth lnP {}",
        res.lnp_peak,
        ev_truth.lnp
    );
    // invariant 2: if we are in the truth mode, φ1 matches within 5σ
    if (res.theta_hat[1] - truth[1]).abs() < 0.3 {
        let hess =
            gpfast::gp::profiled_hessian(&model, &data.t, &data.y, &res.theta_hat).unwrap();
        let prior = BoxPrior::for_model(&model, &data.span().unwrap());
        let ev = gpfast::evidence::laplace_evidence(
            300,
            &prior,
            &gpfast::priors::ScalePrior::default(),
            &res.theta_hat,
            res.lnp_peak,
            &hess,
        )
        .unwrap();
        let dev = (res.theta_hat[1] - truth[1]).abs();
        assert!(
            dev < 5.0 * ev.sigma[1].max(0.01),
            "φ1 = {} vs truth {} (σ = {})",
            res.theta_hat[1],
            truth[1],
            ev.sigma[1]
        );
    }
}
