//! Persistence round-trip suite for on-disk [`TrainedModel`] artifacts.
//!
//! Acceptance bar: save→load→predict is **bit-identical** to the
//! in-memory predictor for every roster entrant; corrupt, truncated and
//! version-mismatched files return clean errors (no panics); and a
//! serving session restored via `ServeSession::from_artifacts` reaches
//! its first prediction with **zero** profiled-likelihood evaluations —
//! asserted through the per-thread [`CounterSnapshot`] deltas, so this
//! binary's tests run concurrently (no process-global counter races to
//! serialise behind a mutex).
//!
//! Since format version 3 every artifact ends in a CRC32 trailer; the
//! corrupt-byte matrix here patches payload bytes **and refreshes the
//! trailer** so the field-level validation stays exercised, then checks
//! separately that an unrefreshed flip is caught by the checksum alone —
//! including the silent-corruption case version 2 used to accept.

use std::path::PathBuf;

use gpfast::coordinator::artifact::crc32;
use gpfast::coordinator::{
    AlignedBlob, ArtifactView, ModelSpec, NestedReport, ServeSession, TrainResult, TrainedModel,
};
use gpfast::data::synthetic::{ard3_dataset, table1_dataset};
use gpfast::data::Dataset;
use gpfast::evidence::LaplaceEvidence;
use gpfast::gp::{profiled, CounterSnapshot};
use gpfast::linalg::Matrix;
use gpfast::priors::BoxPrior;
use gpfast::runtime::ExecutionContext;

/// Rewrite the version-3 CRC32 trailer after an in-place byte patch, so
/// a corruption reaches the field validation it targets instead of dying
/// at the checksum gate.
fn refresh_crc(bytes: &mut [u8]) {
    let split = bytes.len() - 4;
    let crc = crc32(&bytes[..split]);
    bytes[split..].copy_from_slice(&crc.to_le_bytes());
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gpfast_artifact_{}_{tag}.bin", std::process::id()))
}

/// Build a deterministic TrainedModel for `spec` without running the
/// optimiser: one profiled evaluation at the prior mid-point plus a
/// hand-filled evidence block (persistence is about serialisation, not
/// about evidence quality).
fn make_artifact(spec: ModelSpec, data: &Dataset, ln_z: f64, with_nested: bool) -> TrainedModel {
    let sigma_n = 0.1;
    let model = spec.build(sigma_n);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut theta: Vec<f64> =
        prior.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
    prior.project(&mut theta);
    let ev = profiled::eval(&model, &data.t, &data.y, &theta).expect("mid-prior eval");
    let m = model.dim();
    TrainedModel {
        spec,
        sigma_n,
        param_names: model.kernel.names(),
        train: TrainResult {
            theta_hat: theta,
            lnp_peak: ev.lnp,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            peak_eval: ev,
            converged: true,
            n_evals: 42,
            n_modes: 1,
            restart_values: vec![-1.5, -2.25, -7.0],
        },
        evidence: LaplaceEvidence {
            ln_z,
            ln_p_peak: -10.0,
            ln_det_h: 3.25,
            ln_volume: 1.5,
            marg_const: 0.75,
            sigma: vec![0.125; m],
            covariance: Matrix::eye(m),
            suspect: false,
        },
        nested: with_nested.then(|| NestedReport {
            ln_z: ln_z - 0.5,
            ln_z_err: 0.25,
            n_evals: 20000,
            information: 7.5,
            wall_secs: 12.0,
        }),
        warm_started: with_nested,
        restarts: 3,
        wall_secs: 1.25,
    }
}

/// Every roster entrant round-trips bit-identically: all scalar fields,
/// the packed factor (via lnp/logdet), α, and — the serving acceptance —
/// the first prediction of the reloaded predictor.
#[test]
fn save_load_round_trip_is_bit_identical_for_every_roster_entrant() {
    let data = table1_dataset(24, 0.1, 901);
    let exec = ExecutionContext::seq();
    let specs = [
        ModelSpec::K1,
        ModelSpec::K2,
        ModelSpec::K3,
        ModelSpec::WendlandSe,
        ModelSpec::WendlandM32,
        ModelSpec::WendlandM52,
    ];
    for (i, spec) in specs.into_iter().enumerate() {
        let name = spec.name();
        let tm = make_artifact(spec, &data, -10.0 - i as f64, i % 2 == 0);
        let path = tmp_path(name);
        tm.save(&path, &data).expect("save");
        let (tm2, data2) = TrainedModel::load(&path).expect("load");
        let _ = std::fs::remove_file(&path);
        // dataset round trip
        assert_eq!(data2.t, data.t, "{name}: t");
        assert_eq!(data2.y, data.y, "{name}: y");
        assert_eq!(data2.label, data.label, "{name}: label");
        // spec + scalars
        assert_eq!(tm2.spec, tm.spec, "{name}");
        assert_eq!(tm2.sigma_n, tm.sigma_n);
        assert_eq!(tm2.param_names, tm.param_names);
        assert_eq!(tm2.train.theta_hat, tm.train.theta_hat);
        assert_eq!(tm2.train.lnp_peak, tm.train.lnp_peak);
        assert_eq!(tm2.train.sigma_f_hat2, tm.train.sigma_f_hat2);
        assert_eq!(tm2.train.converged, tm.train.converged);
        assert_eq!(tm2.train.n_evals, tm.train.n_evals);
        assert_eq!(tm2.train.n_modes, tm.train.n_modes);
        assert_eq!(tm2.train.restart_values, tm.train.restart_values);
        assert_eq!(tm2.train.jitter, tm.train.jitter, "{name}: recorded jitter");
        assert_eq!(tm2.train.peak_eval.lnp, tm.train.peak_eval.lnp);
        assert_eq!(tm2.train.peak_eval.alpha, tm.train.peak_eval.alpha);
        assert_eq!(
            tm2.train.peak_eval.chol.logdet(),
            tm.train.peak_eval.chol.logdet(),
            "{name}: maintained logdet must restore verbatim"
        );
        // evidence + nested
        assert_eq!(tm2.evidence.ln_z, tm.evidence.ln_z);
        assert_eq!(tm2.evidence.sigma, tm.evidence.sigma);
        assert_eq!(
            tm2.evidence.covariance.max_abs_diff(&tm.evidence.covariance),
            0.0
        );
        assert_eq!(tm2.evidence.suspect, tm.evidence.suspect);
        assert_eq!(tm2.nested.is_some(), tm.nested.is_some());
        if let (Some(a), Some(b)) = (&tm2.nested, &tm.nested) {
            assert_eq!(a.ln_z, b.ln_z);
            assert_eq!(a.n_evals, b.n_evals);
        }
        assert_eq!(tm2.warm_started, tm.warm_started);
        assert_eq!(tm2.restarts, tm.restarts);
        assert_eq!(tm2.wall_secs, tm.wall_secs);
        // the serving acceptance: reloaded predictor serves the same bits
        let p_mem = tm.predictor(&data).expect("in-memory predictor");
        let p_disk = tm2.predictor(&data2).expect("reloaded predictor");
        let t_star: Vec<f64> = (0..20).map(|q| 0.3 + 1.17 * q as f64).collect();
        let a = p_mem.predict_batch(&t_star, &exec);
        let b = p_disk.predict_batch(&t_star, &exec);
        assert_eq!(a.mean, b.mean, "{name}: reloaded means must be bit-identical");
        assert_eq!(a.sd, b.sd, "{name}: reloaded sds must be bit-identical");
        assert_eq!(p_mem.lnp(), p_disk.lnp(), "{name}: lnp");
        assert_eq!(p_mem.sigma_f_hat2(), p_disk.sigma_f_hat2(), "{name}: σ̂²");
    }
}

/// A session restored from disk reaches its first prediction with zero
/// profiled-likelihood evaluations, and serves bit-identically to the
/// in-memory router over the same artifacts.
#[test]
fn from_artifacts_serves_first_prediction_with_zero_evals() {
    let data = table1_dataset(24, 0.1, 907);
    let tm_a = make_artifact(ModelSpec::K1, &data, -10.0, false);
    let tm_b = make_artifact(ModelSpec::K2, &data, -12.0, false);
    let path_a = tmp_path("session_k1");
    let path_b = tmp_path("session_k2");
    tm_a.save(&path_a, &data).unwrap();
    tm_b.save(&path_b, &data).unwrap();
    let mem = ServeSession::from_tournament(
        &[tm_a, tm_b],
        &data,
        ExecutionContext::seq(),
    )
    .unwrap();
    let t_star: Vec<f64> = (0..32).map(|q| 0.1 + 0.77 * q as f64).collect();
    let want = mem.predict(&t_star);

    // ---- the counter-gated leg: load + first predict, no evaluations
    // (per-thread snapshot: the sequential context keeps all work here)
    let snap = CounterSnapshot::take();
    let restored =
        ServeSession::from_artifacts(&[&path_a, &path_b], ExecutionContext::seq()).unwrap();
    let got = restored.predict(&t_star);
    assert_eq!(
        snap.delta().evals,
        0,
        "restart-from-artifact must not pay any likelihood evaluation"
    );
    assert_eq!(restored.n_models(), 2);
    assert_eq!(restored.spec().name(), "k1", "stored evidence must rank the router");
    assert_eq!(got.mean, want.mean, "restored session must serve identical bits");
    assert_eq!(got.sd, want.sd);
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);

    // mismatched datasets across artifacts are rejected
    let other = table1_dataset(24, 0.1, 911);
    let tm_c = make_artifact(ModelSpec::K1, &other, -9.0, false);
    let path_c = tmp_path("session_other");
    tm_c.save(&path_c, &other).unwrap();
    let tm_d = make_artifact(ModelSpec::K2, &data, -11.0, false);
    let path_d = tmp_path("session_data");
    tm_d.save(&path_d, &data).unwrap();
    assert!(
        ServeSession::from_artifacts(&[&path_c, &path_d], ExecutionContext::seq()).is_err(),
        "artifacts from different datasets must not silently mix"
    );
    let _ = std::fs::remove_file(&path_c);
    let _ = std::fs::remove_file(&path_d);
}

/// Corrupt, truncated and version-mismatched files all surface as clean
/// errors — never panics, never huge allocations.
#[test]
fn corrupt_truncated_and_mismatched_files_error_cleanly() {
    let data = table1_dataset(16, 0.1, 913);
    let tm = make_artifact(ModelSpec::K1, &data, -8.0, true);
    let path = tmp_path("corrupt");
    tm.save(&path, &data).unwrap();
    let good = std::fs::read(&path).unwrap();

    // truncation at a spread of byte lengths, including mid-header
    for cut in [0usize, 4, 7, 8, 11, 12, 40, good.len() / 2, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        let err = TrainedModel::load(&path).expect_err(&format!("truncated at {cut}"));
        assert!(!format!("{err}").is_empty());
    }

    // wrong magic
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    let err = TrainedModel::load(&path).expect_err("bad magic");
    assert!(format!("{err}").contains("magic"), "unexpected: {err}");

    // version mismatch
    let mut bad = good.clone();
    bad[8] = 0xEE; // version u32 LE starts at byte 8
    std::fs::write(&path, &bad).unwrap();
    let err = TrainedModel::load(&path).expect_err("version mismatch");
    assert!(format!("{err}").contains("version"), "unexpected: {err}");

    // a corrupted length field must be rejected before allocation — the
    // trailer is refreshed so the length check itself does the rejecting
    let mut bad = good.clone();
    // dataset n (u64) sits right after magic+version+label; find the
    // label length to locate it
    let label_len = u32::from_le_bytes([good[12], good[13], good[14], good[15]]) as usize;
    let n_off = 16 + label_len;
    for b in &mut bad[n_off..n_off + 8] {
        *b = 0xFF;
    }
    refresh_crc(&mut bad);
    std::fs::write(&path, &bad).unwrap();
    assert!(TrainedModel::load(&path).is_err(), "oversized length field accepted");

    // an empty dataset (n = 0) is rejected up front — downstream code
    // may index the first training point
    let mut bad = good.clone();
    for b in &mut bad[n_off..n_off + 8] {
        *b = 0;
    }
    refresh_crc(&mut bad);
    std::fs::write(&path, &bad).unwrap();
    assert!(TrainedModel::load(&path).is_err(), "empty dataset accepted");

    // trailing garbage is flagged even with a valid trailer
    let mut bad = good.clone();
    bad.extend_from_slice(&[0u8; 16]);
    refresh_crc(&mut bad);
    std::fs::write(&path, &bad).unwrap();
    assert!(TrainedModel::load(&path).is_err(), "trailing bytes accepted");

    // unknown spec name: corrupt the spec string in place (it follows
    // the dataset block) — rejected with a model error, not a panic
    let spec_off = n_off + 8 + 16 * data.len() + 4;
    let mut bad = good.clone();
    bad[spec_off] = b'z';
    refresh_crc(&mut bad);
    std::fs::write(&path, &bad).unwrap();
    assert!(TrainedModel::load(&path).is_err(), "unknown spec accepted");

    // missing file
    let _ = std::fs::remove_file(&path);
    assert!(TrainedModel::load(&path).is_err());
}

/// Locate the little-endian byte pattern of a known f64 in the artifact
/// stream (the values below are computed, non-round numbers — a
/// collision with an earlier field is vanishingly unlikely and would
/// fail loudly as a wrong error message).
fn find_f64(hay: &[u8], v: f64) -> usize {
    let pat = v.to_le_bytes();
    hay.windows(8).position(|w| w == pat).expect("known f64 not found in artifact bytes")
}

/// Artifacts with structurally valid framing but non-finite payloads —
/// NaN/∞ in θ̂, α, the factor diagonal, the recorded jitter or the
/// dataset itself — must be rejected at hydration with clean errors.
/// These are exactly the corruptions truncation tests cannot catch: the
/// lengths all check out, only the numbers are poison.
#[test]
fn non_finite_artifact_fields_are_rejected() {
    let data = table1_dataset(16, 0.1, 917);
    let tm = make_artifact(ModelSpec::K1, &data, -8.0, false);
    let path = tmp_path("nonfinite");
    tm.save(&path, &data).unwrap();
    let good = std::fs::read(&path).unwrap();

    let corrupt_at = |off: usize, v: f64, what: &str| {
        let mut bad = good.clone();
        bad[off..off + 8].copy_from_slice(&v.to_le_bytes());
        // refreshed trailer: the poison value, not the checksum, must be
        // what the loader rejects
        refresh_crc(&mut bad);
        std::fs::write(&path, &bad).unwrap();
        let err = TrainedModel::load(&path)
            .expect_err(&format!("{what} = {v} must not hydrate"));
        let msg = format!("{err:#}");
        assert!(msg.contains("corrupt artifact") || msg.contains("non-finite"), "{what}: {msg}");
    };

    // θ̂[0] → NaN
    corrupt_at(find_f64(&good, tm.train.theta_hat[0]), f64::NAN, "theta_hat[0]");
    // α[3] → ∞
    corrupt_at(find_f64(&good, tm.train.peak_eval.alpha[3]), f64::INFINITY, "alpha[3]");
    // factor diagonal L[0][0] — the first row (one value) follows the
    // stored logdet — → NaN, and → a negative value (PD factors need
    // strictly positive diagonals)
    let l00 = find_f64(&good, tm.train.peak_eval.chol.logdet()) + 8;
    corrupt_at(l00, f64::NAN, "L[0][0] NaN");
    corrupt_at(l00, -1.0, "L[0][0] negative");
    // recorded ladder jitter (follows the last restart value) → NaN and
    // → negative (jitter is an applied magnitude, never below zero)
    let jit = find_f64(&good, -7.0) + 8;
    corrupt_at(jit, f64::NAN, "jitter NaN");
    corrupt_at(jit, -1.0e-6, "jitter negative");
    // a NaN training input: the Dataset boundary itself must refuse it
    corrupt_at(find_f64(&good, data.t[5]), f64::NAN, "t[5]");
    corrupt_at(find_f64(&good, data.y[5]), f64::NEG_INFINITY, "y[5]");

    // and the pristine bytes still load — the corruptions above were
    // the only problem
    std::fs::write(&path, &good).unwrap();
    TrainedModel::load(&path).expect("pristine artifact must hydrate");
    let _ = std::fs::remove_file(&path);
}

/// What the CRC trailer exists for: a single flipped payload byte —
/// subtle enough to keep every length and finiteness check happy — is
/// caught by the version-3 checksum, and demonstrably was *not*
/// catchable before: the same corrupted body re-framed as version 2
/// loads "successfully" with silently wrong data (which also proves the
/// prior-version read-compat path).
#[test]
fn checksum_catches_payload_flip_that_version2_accepted() {
    let data = table1_dataset(16, 0.1, 929);
    let tm = make_artifact(ModelSpec::K1, &data, -8.0, false);
    let path = tmp_path("crcflip");
    tm.save(&path, &data).unwrap();
    let good = std::fs::read(&path).unwrap();

    // flip the lowest mantissa bit of y[5]: still finite, same lengths,
    // wrong by one ulp — invisible to every structural check
    let off = find_f64(&good, data.y[5]);
    let mut bad = good.clone();
    bad[off] ^= 0x01;
    std::fs::write(&path, &bad).unwrap();
    let err = TrainedModel::load(&path).expect_err("flipped payload byte");
    let msg = format!("{err:#}");
    assert!(msg.contains("CRC32"), "want the checksum complaint, got: {msg}");

    // strip the trailer and rewrite the version field: the corrupted
    // body now claims to be version 2 and hydrates without complaint —
    // the silent-corruption window the trailer closes — while genuine
    // v2 files stay readable through the same arm
    let mut v2 = bad[..bad.len() - 4].to_vec();
    v2[8] = 2; // version u32 LE starts at byte 8
    std::fs::write(&path, &v2).unwrap();
    let (_tm2, data2) = TrainedModel::load(&path).expect("v2 framing must stay readable");
    assert_ne!(data2.y[5], data.y[5], "v2 had no defence against the flip");
    assert_eq!(data2.y[4], data.y[4], "only the flipped value differs");
    let _ = std::fs::remove_file(&path);
}

/// The committed fixture files (tools/make_golden_artifacts.py — an
/// independent Python encoder, not this crate) pin the v2 and v3 wire
/// formats across refactors: every future build must keep hydrating
/// artifacts persisted by older builds, byte layout and all.
#[test]
fn committed_golden_fixtures_stay_readable() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data");
    for version in [2u32, 3] {
        let path = dir.join(format!("golden_v{version}.gpfast"));
        let bytes = std::fs::read(&path)
            .unwrap_or_else(|e| panic!("committed fixture {} missing: {e}", path.display()));
        assert_eq!(
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
            version,
            "fixture file carries the wrong version field"
        );
        let (tm, data) = TrainedModel::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("golden v{version} fixture must hydrate: {e:#}"));
        // dataset: t = 1..8, y = sin(0.7 t) + 0.05 t (the generator's
        // recipe — t is exact, y within libm cross-language round-off)
        assert_eq!(data.label, "golden-fixture");
        assert_eq!(data.len(), 8);
        assert_eq!(data.t, (1..=8).map(f64::from).collect::<Vec<_>>());
        for (k, &y) in data.y.iter().enumerate() {
            let want = (0.7 * data.t[k]).sin() + 0.05 * data.t[k];
            assert!((y - want).abs() < 1e-12, "y[{k}] = {y} vs {want}");
        }
        // model block, exactly as the generator wrote it
        assert_eq!(tm.spec.name(), "k1");
        assert_eq!(tm.sigma_n, 0.1);
        assert_eq!(tm.param_names, vec!["phi0", "phi1", "xi1"]);
        assert_eq!(tm.train.theta_hat, vec![0.4, 1.3, 2.0]);
        assert_eq!(tm.train.sigma_f_hat2, 1.25);
        assert!(tm.train.converged);
        assert_eq!(tm.train.n_evals, 42);
        assert_eq!(tm.train.jitter, 0.0);
        assert_eq!(tm.evidence.sigma, vec![0.1, 0.2, 0.3]);
        assert!(tm.nested.is_none());
        assert!(!tm.warm_started);
        assert_eq!(tm.restarts, 3);
        assert_eq!(tm.wall_secs, 0.125);
        // the stored factor is live: a predictor builds and serves
        // finite values straight off the fixture bytes
        let p = tm.predictor(&data).expect("fixture predictor");
        let pred = p.predict_batch(&[2.5, 6.75], &ExecutionContext::seq());
        assert!(
            pred.mean.iter().chain(pred.sd.iter()).all(|v| v.is_finite()),
            "fixture predictions must be finite"
        );
    }
    // the two fixtures encode the same artifact: v3 is the v2 body with
    // the version field bumped plus the 4-byte CRC trailer
    let v2 = std::fs::read(dir.join("golden_v2.gpfast")).unwrap();
    let v3 = std::fs::read(dir.join("golden_v3.gpfast")).unwrap();
    assert_eq!(v3.len(), v2.len() + 4, "v3 adds exactly the CRC32 trailer");
    assert_eq!(&v3[12..v2.len()], &v2[12..], "fixture bodies must agree after the version field");
}

/// Format v4 round-trips bit-identically for every roster entrant and
/// serves exactly the same bits as the v3 encoding of the same model;
/// the zero-copy view borrows the payload in place on an 8-aligned
/// buffer. A compressed encode at a tight tolerance is always safe: the
/// encoder falls back to the packed layout when truncation would not
/// shrink the artifact, and predictive means stay bit-identical either
/// way because α/t/y/ϑ̂ are stored exactly.
#[test]
fn v4_round_trip_is_bit_identical_and_matches_v3() {
    let data = table1_dataset(24, 0.1, 937);
    let exec = ExecutionContext::seq();
    let t_star: Vec<f64> = (0..20).map(|q| 0.3 + 1.17 * q as f64).collect();
    let specs = [
        ModelSpec::K1,
        ModelSpec::K2,
        ModelSpec::K3,
        ModelSpec::WendlandSe,
        ModelSpec::WendlandM32,
        ModelSpec::WendlandM52,
    ];
    for (i, spec) in specs.into_iter().enumerate() {
        let name = spec.name();
        let tm = make_artifact(spec, &data, -10.0 - i as f64, i % 2 == 1);
        let v3 = tm.to_bytes(&data).expect("encode v3");
        let v4 = tm.to_bytes_v4(&data, None).expect("encode v4");
        assert_eq!(u32::from_le_bytes(v4[8..12].try_into().unwrap()), 4, "{name}: version");

        // the view parses without materialising the numeric payload and
        // borrows every block in place off an 8-aligned buffer
        let blob = AlignedBlob::from_slice(&v4);
        let view = ArtifactView::parse(&blob).expect("v4 view");
        assert!(view.zero_copy(), "{name}: aligned buffer must hydrate without copies");
        assert!(!view.compressed(), "{name}: no compression was requested");
        assert_eq!(view.n(), data.len());
        assert_eq!(view.chol_dim(), data.len());
        assert_eq!(view.spec().name(), name);
        assert_eq!(view.t(), &data.t[..], "{name}: borrowed t block");
        assert_eq!(view.y(), &data.y[..], "{name}: borrowed y block");
        assert_eq!(view.alpha(), &tm.train.peak_eval.alpha[..], "{name}: borrowed α block");
        assert_eq!(view.theta(), &tm.train.theta_hat[..]);
        assert_eq!(view.logdet(), tm.train.peak_eval.chol.logdet());
        view.validate_payload().expect("pristine payload must validate");

        // both containers hydrate to the same model and serve the same bits
        let (tm3, d3) = TrainedModel::from_bytes(&v3).expect("v3 load");
        let (tm4, d4) = TrainedModel::from_bytes(&v4).expect("v4 load");
        assert_eq!(d4.t, d3.t, "{name}");
        assert_eq!(d4.y, d3.y);
        assert_eq!(d4.label, d3.label);
        assert_eq!(tm4.spec, tm3.spec);
        assert_eq!(tm4.sigma_n, tm3.sigma_n);
        assert_eq!(tm4.param_names, tm3.param_names);
        assert_eq!(tm4.train.theta_hat, tm3.train.theta_hat);
        assert_eq!(tm4.train.lnp_peak, tm3.train.lnp_peak);
        assert_eq!(tm4.train.restart_values, tm3.train.restart_values);
        assert_eq!(tm4.train.jitter, tm3.train.jitter);
        assert_eq!(tm4.train.peak_eval.alpha, tm3.train.peak_eval.alpha);
        assert_eq!(tm4.train.peak_eval.chol.logdet(), tm3.train.peak_eval.chol.logdet());
        assert_eq!(tm4.evidence.ln_z, tm3.evidence.ln_z);
        assert_eq!(tm4.nested.is_some(), tm3.nested.is_some());
        let a = tm3.predictor(&d3).unwrap().predict_batch(&t_star, &exec);
        let b = tm4.predictor(&d4).unwrap().predict_batch(&t_star, &exec);
        assert_eq!(b.mean, a.mean, "{name}: v4 means must be bit-identical to v3");
        assert_eq!(b.sd, a.sd, "{name}: v4 sds must be bit-identical to v3");

        // compressed encode: never larger, means never perturbed, sds
        // within the documented truncation tolerance (exact when the
        // encoder falls back to the packed layout)
        let comp = tm.to_bytes_v4(&data, Some(1e-6)).expect("encode compressed");
        assert!(
            comp.len() <= v4.len(),
            "{name}: compression must never grow the artifact ({} vs {})",
            comp.len(),
            v4.len()
        );
        let (tmc, dc) = TrainedModel::from_bytes(&comp).expect("compressed load");
        let c = tmc.predictor(&dc).unwrap().predict_batch(&t_star, &exec);
        assert_eq!(c.mean, a.mean, "{name}: compressed means must stay bit-identical");
        for (got, want) in c.sd.iter().zip(&a.sd) {
            assert!(got.is_finite() && *got >= 0.0, "{name}: compressed sd {got}");
            assert!(
                (got - want).abs() <= 2e-2 * want.abs() + 1e-4,
                "{name}: compressed sd outside tolerance: {got} vs {want}"
            );
        }
    }
}

/// The v4 corruption matrix: truncated buffers, unrefreshed bit flips,
/// unknown flags, rank/layout contract violations, nonzero alignment
/// padding and CRC-refreshed payload poison all fail hydration with
/// clean errors — never panics, never UB on the zero-copy path.
#[test]
fn v4_corruption_matrix_errors_cleanly() {
    let data = table1_dataset(16, 0.1, 941);
    let tm = make_artifact(ModelSpec::K1, &data, -8.0, true);
    let good = tm.to_bytes_v4(&data, None).expect("encode v4");

    // pristine bytes hydrate through the version-dispatching reader
    let (tm0, d0) = TrainedModel::from_bytes(&good).expect("pristine v4");
    assert_eq!(d0.t, data.t);
    assert_eq!(tm0.train.peak_eval.alpha, tm.train.peak_eval.alpha);

    // truncation at a spread of cuts: empty, mid-magic, mid-header,
    // header-only, mid-meta, mid-block, one-short
    for cut in [0usize, 5, 8, 12, 24, 40, 63, 64, 100, good.len() / 2, good.len() - 1] {
        let err = TrainedModel::from_bytes(&good[..cut])
            .expect_err(&format!("truncated at {cut} accepted"));
        assert!(!format!("{err}").is_empty());
    }

    let n = data.len();
    let meta_len = u64::from_le_bytes(good[48..56].try_into().unwrap()) as usize;
    let blocks_off = u64::from_le_bytes(good[56..64].try_into().unwrap()) as usize;
    assert_eq!(blocks_off % 8, 0, "layout contract: block section must be 8-aligned");
    assert_eq!(
        blocks_off,
        (64 + meta_len + 7) / 8 * 8,
        "layout contract: blocks_off is the 8-aligned meta end"
    );
    let alpha_off = blocks_off + 2 * n * 8; // t and y blocks precede α
    let l00_off = alpha_off + n * 8; // packed factor follows α

    // a single flipped payload bit with a stale trailer: the checksum
    // alone does the rejecting, before any field is trusted
    let mut bad = good.clone();
    bad[alpha_off] ^= 0x01;
    let err = TrainedModel::from_bytes(&bad).expect_err("unrefreshed flip");
    assert!(format!("{err:#}").contains("CRC32"), "want checksum complaint, got: {err:#}");

    // every patch below refreshes the trailer, so the targeted
    // validation — not the checksum — must reject

    // unknown flag bits
    let mut bad = good.clone();
    bad[13] = 0x80;
    refresh_crc(&mut bad);
    let err = TrainedModel::from_bytes(&bad).expect_err("unknown flags");
    assert!(format!("{err:#}").contains("flag"), "unexpected: {err:#}");

    // rank field set on an uncompressed artifact
    let mut bad = good.clone();
    bad[32..40].copy_from_slice(&3u64.to_le_bytes());
    refresh_crc(&mut bad);
    let err = TrainedModel::from_bytes(&bad).expect_err("rank without flag");
    assert!(format!("{err:#}").contains("rank"), "unexpected: {err:#}");

    // compressed-block rank out of range: 0, dim+1 and u64::MAX are all
    // rejected by the rank/dim contract before any size arithmetic
    for rank in [0u64, n as u64 + 1, u64::MAX] {
        let mut bad = good.clone();
        bad[12] |= 0x01; // set FLAG_COMPRESSED
        bad[32..40].copy_from_slice(&rank.to_le_bytes());
        refresh_crc(&mut bad);
        let err =
            TrainedModel::from_bytes(&bad).expect_err(&format!("compressed rank {rank} accepted"));
        assert!(format!("{err:#}").contains("rank"), "rank {rank}: unexpected: {err:#}");
    }

    // blocks_off pointing away from the aligned meta end
    let mut bad = good.clone();
    bad[56..64].copy_from_slice(&((blocks_off + 8) as u64).to_le_bytes());
    refresh_crc(&mut bad);
    assert!(TrainedModel::from_bytes(&bad).is_err(), "skewed blocks_off accepted");

    // trailing garbage beyond the declared layout, even with a valid trailer
    let mut bad = good.clone();
    bad.extend_from_slice(&[0u8; 8]);
    refresh_crc(&mut bad);
    assert!(TrainedModel::from_bytes(&bad).is_err(), "trailing bytes accepted");

    // nonzero alignment padding between meta and blocks: sweep dataset
    // label lengths until the meta stream leaves pad bytes (7 of 8
    // consecutive lengths do), then dirty the last pad byte
    let mut padded = None;
    for extra in 0..8 {
        let label = format!("pad{}", "x".repeat(extra));
        let d = Dataset::new(data.t.clone(), data.y.clone(), label);
        let b = make_artifact(ModelSpec::K1, &d, -8.0, true).to_bytes_v4(&d, None).unwrap();
        let ml = u64::from_le_bytes(b[48..56].try_into().unwrap()) as usize;
        if ml % 8 != 0 {
            padded = Some(b);
            break;
        }
    }
    let mut bad = padded.expect("some label parity must leave alignment padding");
    let bo = u64::from_le_bytes(bad[56..64].try_into().unwrap()) as usize;
    bad[bo - 1] = 0xAA;
    refresh_crc(&mut bad);
    let err = TrainedModel::from_bytes(&bad).expect_err("nonzero padding");
    assert!(format!("{err:#}").contains("padding"), "unexpected: {err:#}");

    // CRC-refreshed payload poison: the validate layer, not the parser,
    // must reject non-finite α and a non-positive factor diagonal
    let mut bad = good.clone();
    bad[alpha_off..alpha_off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
    refresh_crc(&mut bad);
    let err = TrainedModel::from_bytes(&bad).expect_err("NaN α");
    assert!(format!("{err:#}").contains("non-finite"), "unexpected: {err:#}");

    let mut bad = good.clone();
    bad[l00_off..l00_off + 8].copy_from_slice(&(-1.0f64).to_le_bytes());
    refresh_crc(&mut bad);
    let err = TrainedModel::from_bytes(&bad).expect_err("negative L[0][0]");
    assert!(format!("{err:#}").contains("diagonal"), "unexpected: {err:#}");

    // and the pristine bytes still hydrate — the patches above were the
    // only problem
    TrainedModel::from_bytes(&good).expect("pristine v4 must still hydrate");
}

/// The scenario tier's artifacts: a d = 3 heteroscedastic dataset
/// round-trips through both container versions with its extra input
/// columns and per-point noise intact, the v4 view exposes them through
/// its accessors, and the reloaded predictors serve bit-identical rows.
/// A homoscedastic 1-D artifact keeps carrying **no** input block at all
/// (the committed golden fixtures pin those absolute bytes; here the
/// structural invariant is pinned — decode leaves the nd fields empty).
#[test]
fn nd_heteroscedastic_artifacts_round_trip_v3_and_v4() {
    let data = ard3_dataset(20, 0.1, true, 953);
    assert_eq!(data.d(), 3);
    assert!(data.is_heteroscedastic());
    let exec = ExecutionContext::seq();
    let spec = ModelSpec::SeArd(3);
    let sigma_n = 0.1;
    let model = spec.build(sigma_n);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut theta: Vec<f64> =
        prior.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
    prior.project(&mut theta);
    let ev = profiled::eval_nd_with(
        &model,
        &data.input_cols(),
        data.noise.as_deref(),
        &data.y,
        &theta,
        &exec,
    )
    .expect("nd mid-prior eval");
    let m = model.dim();
    let tm = TrainedModel {
        spec,
        sigma_n,
        param_names: model.kernel.names(),
        train: TrainResult {
            theta_hat: theta,
            lnp_peak: ev.lnp,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            peak_eval: ev,
            converged: true,
            n_evals: 11,
            n_modes: 1,
            restart_values: vec![-2.0],
        },
        evidence: LaplaceEvidence {
            ln_z: -9.5,
            ln_p_peak: -9.5,
            ln_det_h: 0.0,
            ln_volume: 0.0,
            marg_const: 0.0,
            sigma: vec![0.0; m],
            covariance: Matrix::zeros(m, m),
            suspect: false,
        },
        nested: None,
        warm_started: true,
        restarts: 2,
        wall_secs: 0.5,
    };

    // query rows expressed as three query *columns* — the same layout
    // as Dataset::input_cols
    let q1 = vec![2.5, 7.25, 13.0];
    let q2 = vec![1.0, 4.0, 6.5];
    let q3 = vec![0.5, 2.0, 3.5];
    let q: Vec<&[f64]> = vec![&q1, &q2, &q3];
    let want = tm.predictor(&data).expect("in-memory nd predictor").predict_rows(&q, &exec);
    assert!(want.mean.iter().chain(want.sd.iter()).all(|v| v.is_finite()));

    // ---- v3 container
    let v3 = tm.to_bytes(&data).expect("encode v3");
    let (tm3, d3) = TrainedModel::from_bytes(&v3).expect("v3 load");
    assert_eq!(d3.t, data.t);
    assert_eq!(d3.extra, data.extra, "v3 must round-trip the extra input columns");
    assert_eq!(d3.noise, data.noise, "v3 must round-trip the per-point noise");
    assert_eq!(d3.d(), 3);
    assert_eq!(tm3.spec, spec);
    let got3 = tm3.predictor(&d3).expect("v3 predictor").predict_rows(&q, &exec);
    assert_eq!(got3.mean, want.mean, "v3 reloaded rows must be bit-identical");
    assert_eq!(got3.sd, want.sd);

    // ---- v4 container + view accessors
    let v4 = tm.to_bytes_v4(&data, None).expect("encode v4");
    let blob = AlignedBlob::from_slice(&v4);
    let view = ArtifactView::parse(&blob).expect("v4 view");
    assert_eq!(view.d(), 3);
    assert_eq!(view.extra_cols(), &data.extra[..]);
    assert_eq!(view.noise(), data.noise.as_deref());
    view.validate_payload().expect("nd payload must validate");
    let (tm4, d4) = TrainedModel::from_bytes(&v4).expect("v4 load");
    assert_eq!(d4.extra, data.extra, "v4 must round-trip the extra input columns");
    assert_eq!(d4.noise, data.noise, "v4 must round-trip the per-point noise");
    let got4 = tm4.predictor(&d4).expect("v4 predictor").predict_rows(&q, &exec);
    assert_eq!(got4.mean, want.mean, "v4 reloaded rows must be bit-identical");
    assert_eq!(got4.sd, want.sd);

    // homoscedastic 1-D: no input block, nd fields decode empty
    let flat = table1_dataset(12, 0.1, 959);
    let tm_flat = make_artifact(ModelSpec::K1, &flat, -8.0, false);
    let (_, d_flat) =
        TrainedModel::from_bytes(&tm_flat.to_bytes(&flat).unwrap()).unwrap();
    assert_eq!(d_flat.d(), 1);
    assert!(d_flat.extra.is_empty() && d_flat.noise.is_none());
}

/// Deterministic artifact at an explicit σ_n and ϑ (no prior mid-point):
/// the spectral-engagement test below needs a smooth, long-range kernel
/// whose spectrum genuinely collapses.
fn make_artifact_at(
    spec: ModelSpec,
    data: &Dataset,
    sigma_n: f64,
    theta: Vec<f64>,
    ln_z: f64,
) -> TrainedModel {
    let model = spec.build(sigma_n);
    let m = model.dim();
    let ev = profiled::eval(&model, &data.t, &data.y, &theta).expect("eval at theta");
    TrainedModel {
        spec,
        sigma_n,
        param_names: model.kernel.names(),
        train: TrainResult {
            theta_hat: theta,
            lnp_peak: ev.lnp,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            peak_eval: ev,
            converged: true,
            n_evals: 7,
            n_modes: 1,
            restart_values: vec![-1.0],
        },
        evidence: LaplaceEvidence {
            ln_z,
            ln_p_peak: ln_z,
            ln_det_h: 0.0,
            ln_volume: 0.0,
            marg_const: 0.0,
            sigma: vec![0.0; m],
            covariance: Matrix::zeros(m, m),
            suspect: false,
        },
        nested: None,
        warm_started: false,
        restarts: 0,
        wall_secs: 0.0,
    }
}

/// Drive the truncated-spectral block for real: a k1 model with a wide
/// Wendland support (T₀ = e⁵ ≈ 148 ≫ span) and a smooth periodic factor
/// has a collapsing spectrum, so a loose tolerance must engage
/// compression, shrink the artifact, keep predictive means bit-identical
/// (α is stored exactly) and reconstruct sds close to the uncompressed
/// factor's.
#[test]
fn v4_spectral_compression_engages_and_round_trips() {
    let data = table1_dataset(48, 0.1, 947);
    let exec = ExecutionContext::seq();
    // ϑ = [φ₀, φ₁, ξ₁]: T₀ = e⁵, T₁ = e^2.7726 ≈ 16, l ≈ 7.8 — smooth
    // everywhere, no compact-support cutoff inside the span
    let tm = make_artifact_at(ModelSpec::K1, &data, 1e-2, vec![5.0, 2.7726, 0.2], -9.0);
    let t_star: Vec<f64> = (0..24).map(|q| 0.4 + 2.1 * q as f64).collect();
    let want = tm.predictor(&data).expect("control predictor").predict_batch(&t_star, &exec);
    let plain = tm.to_bytes_v4(&data, None).expect("encode packed");

    let mut engaged = 0usize;
    for tol in [1e-3, 1e-4] {
        let comp = tm.to_bytes_v4(&data, Some(tol)).expect("encode compressed");
        let blob = AlignedBlob::from_slice(&comp);
        let view = ArtifactView::parse(&blob).expect("compressed view");
        if !view.compressed() {
            continue; // encoder fell back — counted below
        }
        engaged += 1;
        assert!(
            comp.len() < plain.len(),
            "tol {tol}: engaged compression must shrink the artifact ({} vs {})",
            comp.len(),
            plain.len()
        );
        assert!(view.packed_factor().is_none(), "compressed artifacts carry no packed triangle");
        view.validate_payload().expect("compressed payload must validate");

        let (tmc, dc) = TrainedModel::from_bytes(&comp).expect("compressed hydrate");
        let got = tmc.predictor(&dc).expect("hydrated predictor").predict_batch(&t_star, &exec);
        assert_eq!(got.mean, want.mean, "tol {tol}: means must survive compression bit-identically");
        let sd_max = want.sd.iter().cloned().fold(0.0, f64::max);
        assert!(sd_max.is_finite() && sd_max > 0.0);
        for (g, w) in got.sd.iter().zip(&want.sd) {
            assert!(g.is_finite() && *g >= 0.0, "tol {tol}: compressed sd {g}");
            assert!(
                (g - w).abs() <= 0.25 * sd_max,
                "tol {tol}: compressed sd outside tolerance: {g} vs {w}"
            );
        }
    }
    assert!(
        engaged >= 1,
        "spectral truncation must engage on a collapsed spectrum at loose tolerance"
    );
}
