//! Property tests for **every** kernel in `rust/src/kernels/`, via the
//! `propcheck` mini-framework:
//!
//! 1. **symmetry** — stationarity in the lag: `k(Δt) = k(−Δt)` (so
//!    `k(x, x′) = k(x′, x)`), exactly;
//! 2. **positive definiteness** — the Gram matrix on a random irregular
//!    grid admits a Cholesky factorisation once the standard σ_n² jitter
//!    is on the diagonal;
//! 3. **gradients** — `value_grad` matches central finite differences of
//!    `value` in every hyperparameter, and the `value_grad_hess` Hessian
//!    is symmetric and consistent with FD of the gradient.
//!
//! The kernel zoo below covers each concrete factor (Wendland, Periodic,
//! SquaredExponential, Matern32, Matern52, Amplitude) and both
//! combinators (ProductKernel, SumKernel), including the paper's k₁/k₂.

use gpfast::kernels::{
    paper_k1, paper_k2, Amplitude, ArdKernel, DataSpan, Matern32, Matern52, Periodic,
    ProductKernel, SquaredExponential, StationaryKernel, SumKernel, Wendland,
};
use gpfast::linalg::{Chol, Matrix};
use gpfast::propcheck::{property, Gen};

/// Every kernel under test, freshly built (kernels are not `Clone`).
/// Index 0..N-1 must stay stable across calls — properties draw a kernel
/// by index.
fn build_kernel(idx: usize) -> (&'static str, Box<dyn StationaryKernel>) {
    match idx {
        0 => ("wendland", Box::new(ProductKernel::new(vec![Box::new(Wendland)]))),
        1 => (
            "periodic",
            Box::new(ProductKernel::new(vec![Box::new(Periodic::new(1))])),
        ),
        2 => (
            "squared-exponential",
            Box::new(ProductKernel::new(vec![Box::new(SquaredExponential::new(1))])),
        ),
        3 => (
            "matern32",
            Box::new(ProductKernel::new(vec![Box::new(Matern32::new(1))])),
        ),
        4 => (
            "matern52",
            Box::new(ProductKernel::new(vec![Box::new(Matern52::new(1))])),
        ),
        5 => (
            "amplitude×periodic",
            Box::new(ProductKernel::new(vec![
                Box::new(Amplitude::new(1)),
                Box::new(Periodic::new(1)),
            ])),
        ),
        6 => ("k1", paper_k1(0.1).kernel),
        7 => ("k2", paper_k2(0.1).kernel),
        8 => (
            "se+amp×periodic (sum)",
            Box::new(SumKernel::new(vec![
                Box::new(ProductKernel::new(vec![Box::new(SquaredExponential::new(1))])),
                Box::new(ProductKernel::new(vec![
                    Box::new(Amplitude::new(1)),
                    Box::new(Periodic::new(1)),
                ])),
            ])),
        ),
        _ => unreachable!(),
    }
}

const N_KERNELS: usize = 9;

/// A hyperparameter point drawn uniformly from the interior of the
/// kernel's own prior box (edges excluded so FD probes stay inside),
/// with ordering constraints respected.
fn gen_theta(g: &mut Gen, kernel: &dyn StationaryKernel, span: &DataSpan) -> Vec<f64> {
    let bounds = kernel.bounds(span);
    let mut theta: Vec<f64> = bounds
        .iter()
        .map(|(lo, hi)| {
            let w = hi - lo;
            g.f64(lo + 0.05 * w, hi - 0.05 * w)
        })
        .collect();
    for (i, j) in kernel.ordering_constraints() {
        if theta[i] > theta[j] {
            theta.swap(i, j);
        }
    }
    theta
}

/// Random irregular grid with spacings in [0.3, 2.5].
fn gen_times(g: &mut Gen, max_n: usize) -> Vec<f64> {
    let n = g.usize(6..max_n);
    let mut t = Vec::with_capacity(n);
    let mut acc = 0.0;
    for _ in 0..n {
        acc += g.f64(0.3, 2.5);
        t.push(acc);
    }
    t
}

#[test]
fn every_kernel_is_symmetric_in_the_lag() {
    property("k(Δt) = k(−Δt) for every kernel", 60, |g| {
        let idx = g.usize(0..N_KERNELS);
        let (name, kernel) = build_kernel(idx);
        let span = DataSpan { dt_min: 0.3, dt_max: 40.0 };
        let theta = gen_theta(g, kernel.as_ref(), &span);
        let mut prep = kernel.prepare(&theta);
        for _ in 0..8 {
            let dt = g.f64(0.0, 30.0);
            let (a, b) = (prep.value(dt), prep.value(-dt));
            if a != b {
                return Err(format!("{name}: k({dt}) = {a} but k(−{dt}) = {b}"));
            }
            if !a.is_finite() || a < 0.0 {
                return Err(format!("{name}: k({dt}) = {a} not finite/non-negative"));
            }
        }
        Ok(())
    });
}

#[test]
fn every_kernel_gram_matrix_is_pd_with_jitter() {
    property("Cholesky succeeds on every kernel's jittered Gram", 40, |g| {
        let idx = g.usize(0..N_KERNELS);
        let (name, kernel) = build_kernel(idx);
        let t = gen_times(g, 30);
        let span = DataSpan::from_times(&t).unwrap();
        let theta = gen_theta(g, kernel.as_ref(), &span);
        let mut prep = kernel.prepare(&theta);
        let n = t.len();
        // σ_n²-style diagonal jitter scaled to the kernel's own k(0)
        let jitter = 1e-6 * prep.value(0.0).max(1e-12);
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k[(i, j)] = prep.value(t[i] - t[j]);
            }
            k[(i, i)] += jitter;
        }
        match Chol::factor(&k) {
            Ok(_) => Ok(()),
            Err(e) => Err(format!("{name}: Gram not PD at θ={theta:?}: {e}")),
        }
    });
}

#[test]
fn every_kernel_gradient_matches_finite_differences() {
    property("analytic ∂k/∂ϑ = FD for every kernel", 30, |g| {
        let idx = g.usize(0..N_KERNELS);
        let (name, kernel) = build_kernel(idx);
        let span = DataSpan { dt_min: 0.5, dt_max: 30.0 };
        let theta = gen_theta(g, kernel.as_ref(), &span);
        let m = kernel.dim();
        let dt = g.f64(0.1, 8.0);
        let mut grad = vec![0.0; m];
        let v = kernel.prepare(&theta).value_grad(dt, &mut grad);
        // compact support: the contract says all derivatives are zero
        if v == 0.0 {
            return if grad.iter().all(|&x| x == 0.0) {
                Ok(())
            } else {
                Err(format!("{name}: zero value but nonzero gradient"))
            };
        }
        for a in 0..m {
            let h = 1e-6 * theta[a].abs().max(0.05);
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = kernel.prepare(&tp).value(dt);
            let fm = kernel.prepare(&tm).value(dt);
            let fd = (fp - fm) / (2.0 * h);
            // rel_diff floors the denominator at 1 — the same metric and
            // tolerance the in-crate FD suites use
            if gpfast::math::rel_diff(grad[a], fd) > 5e-4 {
                return Err(format!(
                    "{name}: grad[{a}] at dt={dt} θ={theta:?}: analytic {} vs FD {fd}",
                    grad[a]
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// ARD sweeps — the same three properties on d-dimensional separations,
// over the scenario tier's kernel roster (se/m32/m52 ARD plus the tied
// se-iso parent) and input dimensions d ∈ {1, 2, 3, 5}.
// ---------------------------------------------------------------------

/// ARD input dimensions under sweep (d = 5 exceeds every registry spec
/// on purpose — the kernel layer itself has no d ≤ 3 assumption).
const ARD_DIMS: [usize; 4] = [1, 2, 3, 5];

/// The ARD zoo: family × tied, freshly built for a given input dim.
fn build_ard_kernel(fam: usize, d: usize) -> (String, ArdKernel) {
    match fam {
        0 => (format!("se-ard d={d}"), ArdKernel::se(d)),
        1 => (format!("m32-ard d={d}"), ArdKernel::m32(d)),
        2 => (format!("m52-ard d={d}"), ArdKernel::m52(d)),
        3 => (format!("se-iso d={d}"), ArdKernel::se_iso(d)),
        _ => unreachable!(),
    }
}

const N_ARD_FAMILIES: usize = 4;

#[test]
fn ard_kernels_are_symmetric_in_the_separation_across_dims() {
    property("k(Δx) = k(−Δx) for every ARD kernel, d ∈ {1,2,3,5}", 60, |g| {
        let d = ARD_DIMS[g.usize(0..ARD_DIMS.len())];
        let (name, kernel) = build_ard_kernel(g.usize(0..N_ARD_FAMILIES), d);
        let span = DataSpan { dt_min: 0.3, dt_max: 40.0 };
        let theta = gen_theta(g, &kernel, &span);
        let mut prep = kernel.prepare(&theta);
        for _ in 0..8 {
            let dx: Vec<f64> = (0..d).map(|_| g.f64(-6.0, 6.0)).collect();
            let neg: Vec<f64> = dx.iter().map(|v| -v).collect();
            let (a, b) = (prep.value_nd(&dx), prep.value_nd(&neg));
            if a != b {
                return Err(format!("{name}: k({dx:?}) = {a} but k(−Δx) = {b}"));
            }
            // normalised correlation kernels: finite, in [0, k(0) = 1]
            if !a.is_finite() || a < 0.0 || a > 1.0 {
                return Err(format!("{name}: k({dx:?}) = {a} outside [0, 1]"));
            }
        }
        Ok(())
    });
}

#[test]
fn ard_gram_matrix_is_pd_with_jitter_across_dims() {
    property("Cholesky succeeds on jittered ARD Gram, d ∈ {1,2,3,5}", 40, |g| {
        let d = ARD_DIMS[g.usize(0..ARD_DIMS.len())];
        let (name, kernel) = build_ard_kernel(g.usize(0..N_ARD_FAMILIES), d);
        let span = DataSpan { dt_min: 0.3, dt_max: 40.0 };
        let theta = gen_theta(g, &kernel, &span);
        let mut prep = kernel.prepare(&theta);
        let n = g.usize(6..24);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| g.f64(0.0, 12.0)).collect()).collect();
        let jitter = 1e-6; // k(0) = 1 for every ARD family
        let mut k = Matrix::zeros(n, n);
        let mut dx = vec![0.0; d];
        for i in 0..n {
            for j in 0..n {
                for c in 0..d {
                    dx[c] = x[i][c] - x[j][c];
                }
                k[(i, j)] = prep.value_nd(&dx);
            }
            k[(i, i)] += jitter;
        }
        match Chol::factor(&k) {
            Ok(_) => Ok(()),
            Err(e) => Err(format!("{name}: Gram not PD at θ={theta:?}: {e}")),
        }
    });
}

#[test]
fn ard_gradient_matches_finite_differences_across_dims() {
    property("analytic ∂k/∂φ = FD for ARD kernels, d ∈ {1,2,3,5}", 30, |g| {
        let d = ARD_DIMS[g.usize(0..ARD_DIMS.len())];
        let (name, kernel) = build_ard_kernel(g.usize(0..N_ARD_FAMILIES), d);
        let span = DataSpan { dt_min: 0.5, dt_max: 30.0 };
        let theta = gen_theta(g, &kernel, &span);
        let m = kernel.dim();
        let dx: Vec<f64> = (0..d).map(|_| g.f64(0.1, 4.0)).collect();
        let mut grad = vec![0.0; m];
        let v = kernel.prepare(&theta).value_grad_nd(&dx, &mut grad);
        if !v.is_finite() {
            return Err(format!("{name}: non-finite value {v} at dx={dx:?}"));
        }
        for a in 0..m {
            let h = 1e-6 * theta[a].abs().max(0.05);
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = kernel.prepare(&tp).value_nd(&dx);
            let fm = kernel.prepare(&tm).value_nd(&dx);
            let fd = (fp - fm) / (2.0 * h);
            if gpfast::math::rel_diff(grad[a], fd) > 5e-4 {
                return Err(format!(
                    "{name}: grad[{a}] at dx={dx:?} θ={theta:?}: analytic {} vs FD {fd}",
                    grad[a]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn ard_hessian_is_symmetric_and_matches_fd_of_gradient_across_dims() {
    property("∂²k symmetric + consistent with FD(∂k) for ARD, d ∈ {1,2,3,5}", 20, |g| {
        let d = ARD_DIMS[g.usize(0..ARD_DIMS.len())];
        let (name, kernel) = build_ard_kernel(g.usize(0..N_ARD_FAMILIES), d);
        let span = DataSpan { dt_min: 0.5, dt_max: 30.0 };
        let theta = gen_theta(g, &kernel, &span);
        let m = kernel.dim();
        let dx: Vec<f64> = (0..d).map(|_| g.f64(0.1, 4.0)).collect();
        let mut grad = vec![0.0; m];
        let mut hess = vec![0.0; m * m];
        kernel.prepare(&theta).value_grad_hess_nd(&dx, &mut grad, &mut hess);
        for a in 0..m {
            for b in 0..m {
                let (hab, hba) = (hess[a * m + b], hess[b * m + a]);
                if (hab - hba).abs() > 1e-9 * hab.abs().max(1e-9) {
                    return Err(format!("{name}: H[{a},{b}] = {hab} ≠ H[{b},{a}] = {hba}"));
                }
            }
        }
        for a in 0..m {
            let h = 1e-6 * theta[a].abs().max(0.05);
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let mut gp = vec![0.0; m];
            let mut gm = vec![0.0; m];
            kernel.prepare(&tp).value_grad_nd(&dx, &mut gp);
            kernel.prepare(&tm).value_grad_nd(&dx, &mut gm);
            for b in 0..m {
                let fd = (gp[b] - gm[b]) / (2.0 * h);
                if gpfast::math::rel_diff(hess[a * m + b], fd) > 1e-3 {
                    return Err(format!(
                        "{name}: H[{a},{b}] at dx={dx:?}: analytic {} vs FD {fd}",
                        hess[a * m + b]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn every_kernel_hessian_is_symmetric_and_matches_fd_of_gradient() {
    property("∂²k symmetric + consistent with FD(∂k)", 20, |g| {
        let idx = g.usize(0..N_KERNELS);
        let (name, kernel) = build_kernel(idx);
        let span = DataSpan { dt_min: 0.5, dt_max: 30.0 };
        let theta = gen_theta(g, kernel.as_ref(), &span);
        let m = kernel.dim();
        let dt = g.f64(0.1, 8.0);
        let mut grad = vec![0.0; m];
        let mut hess = vec![0.0; m * m];
        let v = kernel.prepare(&theta).value_grad_hess(dt, &mut grad, &mut hess);
        if v == 0.0 {
            return Ok(());
        }
        for a in 0..m {
            for b in 0..m {
                let (hab, hba) = (hess[a * m + b], hess[b * m + a]);
                if (hab - hba).abs() > 1e-9 * hab.abs().max(1e-9) {
                    return Err(format!("{name}: H[{a},{b}] = {hab} ≠ H[{b},{a}] = {hba}"));
                }
            }
        }
        for a in 0..m {
            let h = 1e-6 * theta[a].abs().max(0.05);
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let mut gp = vec![0.0; m];
            let mut gm = vec![0.0; m];
            kernel.prepare(&tp).value_grad(dt, &mut gp);
            kernel.prepare(&tm).value_grad(dt, &mut gm);
            for b in 0..m {
                let fd = (gp[b] - gm[b]) / (2.0 * h);
                if gpfast::math::rel_diff(hess[a * m + b], fd) > 1e-3 {
                    return Err(format!(
                        "{name}: H[{a},{b}] at dt={dt}: analytic {} vs FD {fd}",
                        hess[a * m + b]
                    ));
                }
            }
        }
        Ok(())
    });
}
