//! Adversarial-shape validation of the `linalg::micro` kernel layer:
//! GEMM/SYRK/TRSM against naive triple-loop references at remainder-heavy
//! and non-square shapes, plus the canonical accumulation-order contract
//! (bit-identical results at any thread count, including the machine
//! maximum).

use gpfast::linalg::micro::{self, Clip};
use gpfast::linalg::{solve_lower, solve_lower_transpose, Chol, ExecutionContext, Matrix};
use gpfast::rng::Xoshiro256;

/// The adversarial size set from the issue: unit, just-below/at/above the
/// MR/NR/TB tile edges, a prime, and a multi-`KC`-straddling size.
const SIZES: [usize; 7] = [1, 7, 31, 32, 33, 97, 256];

fn randv(len: usize, rng: &mut Xoshiro256) -> Vec<f64> {
    (0..len).map(|_| rng.normal()).collect()
}

fn rand_matrix(r: usize, c: usize, rng: &mut Xoshiro256) -> Matrix {
    Matrix::from_vec(r, c, randv(r * c, rng))
}

fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.1;
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
        m[(i, i)] = 4.0;
    }
    m
}

fn max_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).max(2)
}

#[test]
fn gemm_nn_matches_naive_reference_across_shape_grid() {
    let mut rng = Xoshiro256::seed_from_u64(2027);
    for &m in &SIZES {
        for &n in &SIZES {
            for &k in &SIZES {
                let a = randv(m * k, &mut rng);
                let b = randv(k * n, &mut rng);
                let mut c = vec![0.0; m * n];
                micro::gemm_nn(&mut c, n, m, n, k, &a, k, &b, n, 1.0, Clip::None);
                // naive i-j-k reference
                let mut scale = 1.0f64;
                let mut worst = 0.0f64;
                for i in 0..m {
                    for j in 0..n {
                        let mut s = 0.0;
                        for kk in 0..k {
                            s += a[i * k + kk] * b[kk * n + j];
                        }
                        scale = scale.max(s.abs());
                        worst = worst.max((c[i * n + j] - s).abs());
                    }
                }
                assert!(
                    worst / scale < 1e-12,
                    "gemm_nn m={m} n={n} k={k}: rel err {:.3e}",
                    worst / scale
                );
            }
        }
    }
}

#[test]
fn gemm_nt_matches_naive_reference_across_shape_grid() {
    let mut rng = Xoshiro256::seed_from_u64(2029);
    for &m in &SIZES {
        for &n in &SIZES {
            for &k in &SIZES {
                let a = randv(m * k, &mut rng);
                let b = randv(n * k, &mut rng);
                let mut c = vec![0.0; m * n];
                micro::gemm_nt(&mut c, n, m, n, k, &a, k, &b, k, 1.0, Clip::None);
                let mut scale = 1.0f64;
                let mut worst = 0.0f64;
                for i in 0..m {
                    for j in 0..n {
                        let mut s = 0.0;
                        for kk in 0..k {
                            s += a[i * k + kk] * b[j * k + kk];
                        }
                        scale = scale.max(s.abs());
                        worst = worst.max((c[i * n + j] - s).abs());
                    }
                }
                assert!(
                    worst / scale < 1e-12,
                    "gemm_nt m={m} n={n} k={k}: rel err {:.3e}",
                    worst / scale
                );
            }
        }
    }
}

#[test]
fn syrk_lower_clip_matches_naive_triangle_and_leaves_upper_untouched() {
    let mut rng = Xoshiro256::seed_from_u64(2031);
    for &n in &[7usize, 33, 97, 130] {
        for &k in &[1usize, 31, 64] {
            let p = randv(n * k, &mut rng);
            let sentinel = 123.456789;
            let mut c = vec![sentinel; n * n];
            for i in 0..n {
                for j in 0..=i {
                    c[i * n + j] = 1.0;
                }
            }
            micro::gemm_nt(&mut c, n, n, n, k, &p, k, &p, k, -1.0, Clip::Lower(0));
            for i in 0..n {
                for j in 0..n {
                    if j <= i {
                        let mut s = 0.0;
                        for kk in 0..k {
                            s += p[i * k + kk] * p[j * k + kk];
                        }
                        let want = 1.0 - s;
                        assert!(
                            (c[i * n + j] - want).abs() < 1e-11 * want.abs().max(1.0),
                            "syrk n={n} k={k} ({i},{j})"
                        );
                    } else {
                        assert_eq!(c[i * n + j], sentinel, "syrk wrote above diagonal ({i},{j})");
                    }
                }
            }
        }
    }
}

#[test]
fn blocked_row_solves_match_scalar_triangular_solves() {
    let mut rng = Xoshiro256::seed_from_u64(2033);
    for &n in &SIZES {
        let ch = Chol::factor(&random_spd(n, &mut rng)).unwrap();
        let l = ch.factor_matrix();
        for &q in &[1usize, 5] {
            let b = randv(q * n, &mut rng);
            let mut fwd = b.clone();
            micro::solve_lower_rows(l.as_slice(), n, n, &mut fwd, n, q);
            let mut bwd = fwd.clone();
            micro::solve_lower_transpose_rows(l.as_slice(), n, n, &mut bwd, n, q);
            for r in 0..q {
                let mut want = b[r * n..(r + 1) * n].to_vec();
                solve_lower(l, &mut want);
                for j in 0..n {
                    assert!(
                        (fwd[r * n + j] - want[j]).abs() < 1e-10 * want[j].abs().max(1.0),
                        "forward n={n} q={q} ({r},{j})"
                    );
                }
                solve_lower_transpose(l, &mut want);
                for j in 0..n {
                    assert!(
                        (bwd[r * n + j] - want[j]).abs() < 1e-10 * want[j].abs().max(1.0),
                        "backward n={n} q={q} ({r},{j})"
                    );
                }
            }
        }
    }
}

/// The canonical accumulation-order contract at the machine's full
/// parallelism: every ported kernel must be bit-identical to its serial
/// run, including at sizes that straddle every block edge.
#[test]
fn ported_kernels_bit_identical_at_max_threads() {
    let mut rng = Xoshiro256::seed_from_u64(2039);
    let ctx = ExecutionContext::new(max_threads());
    for &n in &[65usize, 129, 320] {
        // matmul (non-square to exercise remainder tiles)
        let a = rand_matrix(n, n + 17, &mut rng);
        let b = rand_matrix(n + 17, n - 3, &mut rng);
        let serial = a.matmul(&b);
        assert_eq!(a.matmul_with(&b, &ctx).max_abs_diff(&serial), 0.0, "matmul n={n}");
        // factor, inverse, multi-RHS solves
        let k = random_spd(n, &mut rng);
        let ch_s = Chol::factor(&k).unwrap();
        let ch_p = Chol::factor_with(&k, &ctx).unwrap();
        assert_eq!(
            ch_p.factor_matrix().max_abs_diff(ch_s.factor_matrix()),
            0.0,
            "factor n={n}"
        );
        assert_eq!(ch_p.inverse_with(&ctx).max_abs_diff(&ch_s.inverse()), 0.0, "inverse n={n}");
        let rhs = rand_matrix(n, 9, &mut rng);
        assert_eq!(
            ch_p.solve_mat_with(&rhs, &ctx).max_abs_diff(&ch_s.solve_mat(&rhs)),
            0.0,
            "solve_mat n={n}"
        );
        let batch = rand_matrix(40, n, &mut rng);
        let mut got_s = batch.clone();
        ch_s.half_solve_rows_with(&mut got_s, &ExecutionContext::seq());
        let mut got_p = batch.clone();
        ch_p.half_solve_rows_with(&mut got_p, &ctx);
        assert_eq!(got_p.max_abs_diff(&got_s), 0.0, "half_solve_rows n={n}");
    }
}

/// Factor → solve residual stays tight through the micro-kernel path.
#[test]
fn micro_kernel_factor_solves_accurately() {
    let mut rng = Xoshiro256::seed_from_u64(2041);
    for &n in &[97usize, 256] {
        let k = random_spd(n, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let b: Vec<f64> = randv(n, &mut rng);
        let x = ch.solve(&b);
        let r = k.matvec(&x);
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-9, "n={n} residual {:.3e}", (r[i] - b[i]).abs());
        }
        // tiled transpose round-trips exactly
        let m = rand_matrix(n, n / 2 + 1, &mut rng);
        assert_eq!(m.transpose().transpose().max_abs_diff(&m), 0.0);
    }
}
