//! End-to-end integration: the full coordinator pipeline on synthetic
//! data — train both models, Laplace-rank them, verify with nested
//! sampling, and check the paper's qualitative claims hold:
//!
//! * the optimiser needs ~10² evaluations/restart vs ~10⁴ for nested
//!   sampling (the 20–50× speed-up, §3(a));
//! * Laplace ln Z_est agrees with nested ln Z_num within a few σ;
//! * with enough data, k₂ (the truth) wins the Bayes factor.

use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
use gpfast::data::synthetic::table1_dataset;
use gpfast::nested::NestedOptions;
use gpfast::rng::Xoshiro256;

fn config(nested: bool) -> PipelineConfig {
    let mut cfg = PipelineConfig::paper_synthetic();
    // the paper: "the typical number of runs required to find the global
    // maximum was ∼ 10" — fewer restarts mistrain k2 on occasion
    cfg.train.multistart.restarts = 10;
    cfg.run_nested = nested;
    // small but honest nested run — keeps the test under a minute
    cfg.nested = NestedOptions { nlive: 150, ..Default::default() };
    cfg.workers = 2;
    cfg
}

#[test]
fn table1_workflow_on_n100() {
    let data = table1_dataset(100, 0.1, 20160125);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut pipeline = ComparisonPipeline::new(config(false));
    let report = pipeline.run(&data, &mut rng).unwrap();
    assert_eq!(report.n, 100);
    let k1 = report.model("k1").expect("k1 trained");
    let k2 = report.model("k2").expect("k2 trained");
    // training found interior peaks with order-unity σ_f
    for m in [k1, k2] {
        assert!(m.lnp_peak.is_finite());
        assert!(m.sigma_f_hat > 0.2 && m.sigma_f_hat < 5.0, "σ_f = {}", m.sigma_f_hat);
    }
    // k2 contains k1: its peak likelihood can not be materially lower
    assert!(
        k2.lnp_peak > k1.lnp_peak - 1.0,
        "nested model should fit at least as well: k2 {} vs k1 {}",
        k2.lnp_peak,
        k1.lnp_peak
    );
    // Bayes factor must be finite and the report renders
    let lnb = report.ln_bayes("k2", "k1").unwrap();
    assert!(lnb.is_finite());
    assert!(report.render().contains("lnZ_est"));
}

#[test]
fn laplace_agrees_with_nested_sampling_k1_n60() {
    // one model, moderate n: the agreement check of Table 1
    let data = table1_dataset(60, 0.1, 7);
    let mut cfg = config(true);
    cfg.models = vec![gpfast::coordinator::ModelSpec::K1];
    let mut rng = Xoshiro256::seed_from_u64(3);
    let mut pipeline = ComparisonPipeline::new(cfg);
    let report = pipeline.run(&data, &mut rng).unwrap();
    let m = &report.models[0];
    let ns = m.nested.as_ref().expect("nested ran");
    let tol = 4.0 * ns.ln_z_err.max(0.3); // generous: small nlive in tests
    assert!(
        (m.ln_z - ns.ln_z).abs() < tol,
        "Laplace {} vs nested {} ± {} (tol {tol})",
        m.ln_z,
        ns.ln_z,
        ns.ln_z_err
    );
    // the paper's cost story: nested needs orders of magnitude more evals
    assert!(
        ns.n_evals > 10 * m.n_evals,
        "nested {} evals vs fast-path {}",
        ns.n_evals,
        m.n_evals
    );
}

#[test]
fn k2_wins_decisively_with_more_data() {
    // Table-1 trend: by n = 200+ the k2-drawn data must prefer k2
    let data = table1_dataset(200, 0.1, 42);
    let mut rng = Xoshiro256::seed_from_u64(5);
    let mut pipeline = ComparisonPipeline::new(config(false));
    let report = pipeline.run(&data, &mut rng).unwrap();
    let lnb = report.ln_bayes("k2", "k1").unwrap();
    assert!(
        lnb > 0.0,
        "expected k2 (truth) to win at n=200, got ln B = {lnb}"
    );
}
