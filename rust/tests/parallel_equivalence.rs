//! Parallel ⇔ serial equivalence for every hot-path kernel behind the
//! [`ExecutionContext`] layer.
//!
//! The parallel kernels are designed to be **bit-identical** to the
//! serial ones (row-tile partitioning, per-element arithmetic order
//! preserved, reductions through per-row buffers summed in row order), so
//! most assertions here are exact equality — any rounding drift is a bug.
//! The one exception is the Hessian pair contraction, whose per-tile
//! partials are folded in tile order: it is checked to tight tolerance.
//!
//! Sizes deliberately straddle the Cholesky block size (NB = 64), the
//! parallel dispatch cutoffs, and ragged tails; thread counts cover
//! 1/2/4 (4 oversubscribes small CI machines — correctness must hold
//! regardless).

use gpfast::gp::profiled::{self, ProfiledEval};
use gpfast::gp::{assemble_cov_grads, assemble_cov_grads_with, full_lnp_grad, full_lnp_grad_with};
use gpfast::kernels::{paper_k2, PaperK2};
use gpfast::linalg::{Chol, ExecutionContext, Matrix};
use gpfast::propcheck::{property, Gen};
use gpfast::rng::Xoshiro256;

fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.05;
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
        m[(i, i)] = 3.0;
    }
    m
}

fn grid(n: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + 0.9 * i as f64).collect()
}

const THREADS: [usize; 3] = [1, 2, 4];

#[test]
fn cholesky_factor_bit_identical_across_threads() {
    let mut rng = Xoshiro256::seed_from_u64(2024);
    // straddle NB = 64 (63/64/65), the per-iteration dispatch cutoff
    // (small trailing blocks stay serial), multi-block and ragged sizes
    for &n in &[16usize, 63, 64, 65, 100, 113, 128, 129, 200, 320] {
        let k = random_spd(n, &mut rng);
        let serial = Chol::factor(&k).unwrap();
        for &nt in &THREADS {
            let ctx = ExecutionContext::new(nt);
            let par = Chol::factor_with(&k, &ctx).unwrap();
            assert_eq!(
                par.factor_matrix().max_abs_diff(serial.factor_matrix()),
                0.0,
                "factor n={n} threads={nt}"
            );
            assert_eq!(par.logdet(), serial.logdet(), "logdet n={n} threads={nt}");
        }
    }
}

#[test]
fn cholesky_inverse_and_solve_mat_bit_identical() {
    let mut rng = Xoshiro256::seed_from_u64(7);
    // 300 exceeds every dispatch cutoff (incl. solve_mat's n ≥ 256)
    for &n in &[40usize, 96, 130, 300] {
        let k = random_spd(n, &mut rng);
        let ch = Chol::factor(&k).unwrap();
        let inv_s = ch.inverse();
        let mut b = Matrix::zeros(n, 7);
        for i in 0..n {
            for j in 0..7 {
                b[(i, j)] = rng.normal();
            }
        }
        let x_s = ch.solve_mat(&b);
        for &nt in &THREADS {
            let ctx = ExecutionContext::new(nt);
            assert_eq!(ch.inverse_with(&ctx).max_abs_diff(&inv_s), 0.0, "inv n={n} t={nt}");
            assert_eq!(ch.solve_mat_with(&b, &ctx).max_abs_diff(&x_s), 0.0, "slv n={n} t={nt}");
        }
    }
}

#[test]
fn matmul_bit_identical() {
    let mut rng = Xoshiro256::seed_from_u64(99);
    let mut a = Matrix::zeros(150, 90);
    let mut b = Matrix::zeros(90, 110);
    for i in 0..150 {
        for j in 0..90 {
            a[(i, j)] = rng.normal();
        }
    }
    for i in 0..90 {
        for j in 0..110 {
            b[(i, j)] = rng.normal();
        }
    }
    let serial = a.matmul(&b);
    for &nt in &THREADS {
        let ctx = ExecutionContext::new(nt);
        assert_eq!(a.matmul_with(&b, &ctx).max_abs_diff(&serial), 0.0, "threads={nt}");
    }
}

#[test]
fn assembled_cov_and_grads_bit_identical() {
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    // straddle the assembly dispatch cutoff (PAR_MIN_N = 64)
    for &n in &[20usize, 63, 64, 65, 130, 257] {
        let t = grid(n);
        let (k_s, g_s) = assemble_cov_grads(&model, &t, &theta);
        for &nt in &THREADS {
            let ctx = ExecutionContext::new(nt);
            let (k_p, g_p) = assemble_cov_grads_with(&model, &t, &theta, &ctx);
            assert_eq!(k_p.max_abs_diff(&k_s), 0.0, "K n={n} threads={nt}");
            for (a, (gp, gs)) in g_p.iter().zip(&g_s).enumerate() {
                assert_eq!(gp.max_abs_diff(gs), 0.0, "dK[{a}] n={n} threads={nt}");
            }
        }
    }
}

#[test]
fn profiled_eval_and_gradient_bit_identical() {
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    for &n in &[80usize, 150, 260] {
        let t = grid(n);
        let y: Vec<f64> = t.iter().map(|&x| (0.23 * x).sin() + 0.1 * (1.7 * x).cos()).collect();
        let (ev_s, g_s) = profiled::eval_grad(&model, &t, &y, &theta).unwrap();
        for &nt in &THREADS {
            let ctx = ExecutionContext::new(nt);
            let (ev_p, g_p) = profiled::eval_grad_with(&model, &t, &y, &theta, &ctx).unwrap();
            assert_eq!(ev_p.lnp, ev_s.lnp, "lnp n={n} threads={nt}");
            assert_eq!(ev_p.sigma_f_hat2, ev_s.sigma_f_hat2, "σ̂² n={n} threads={nt}");
            assert_eq!(g_p, g_s, "gradient n={n} threads={nt}");
        }
    }
}

#[test]
fn full_likelihood_and_gradient_bit_identical() {
    let model = paper_k2(0.1);
    let n = 140;
    let t = grid(n);
    let y: Vec<f64> = t.iter().map(|&x| (0.31 * x).sin()).collect();
    let mut tf = vec![0.15];
    tf.extend(PaperK2::truth());
    let (lnp_s, g_s) = full_lnp_grad(&model, &t, &y, &tf).unwrap();
    for &nt in &THREADS {
        let ctx = ExecutionContext::new(nt);
        let (lnp_p, g_p) = full_lnp_grad_with(&model, &t, &y, &tf, &ctx).unwrap();
        assert_eq!(lnp_p, lnp_s, "threads={nt}");
        assert_eq!(g_p, g_s, "threads={nt}");
    }
}

#[test]
fn profiled_hessian_matches_serial_to_rounding() {
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    let n = 120;
    let t = grid(n);
    let y: Vec<f64> = t.iter().map(|&x| (0.29 * x).sin()).collect();
    let h_s = profiled::profiled_hessian(&model, &t, &y, &theta).unwrap();
    let scale = h_s.fro_norm().max(1.0);
    for &nt in &THREADS {
        let ctx = ExecutionContext::new(nt);
        let h_p = profiled::profiled_hessian_with(&model, &t, &y, &theta, &ctx).unwrap();
        assert!(
            h_p.max_abs_diff(&h_s) < 1e-11 * scale,
            "hessian threads={nt}: {}",
            h_p.max_abs_diff(&h_s)
        );
    }
}

#[test]
fn property_random_shapes_and_thread_counts() {
    property("parallel Cholesky + assembly equal serial", 25, |g: &mut Gen| {
        let n = g.usize(8..180);
        let nt = g.usize(2..5);
        let ctx = ExecutionContext::new(nt);
        let mut rng = Xoshiro256::seed_from_u64(n as u64 * 31 + nt as u64);
        let k = random_spd(n, &mut rng);
        let serial = Chol::factor(&k).unwrap();
        let par = Chol::factor_with(&k, &ctx).unwrap();
        if par.factor_matrix().max_abs_diff(serial.factor_matrix()) != 0.0 {
            return Err(format!("factor differs at n={n} threads={nt}"));
        }
        let model = paper_k2(0.1);
        let t = grid(n);
        let theta = PaperK2::truth();
        let (k_s, g_s) = assemble_cov_grads(&model, &t, &theta);
        let (k_p, g_p) = assemble_cov_grads_with(&model, &t, &theta, &ctx);
        if k_p.max_abs_diff(&k_s) != 0.0 {
            return Err(format!("K differs at n={n} threads={nt}"));
        }
        for (a, (gp, gs)) in g_p.iter().zip(&g_s).enumerate() {
            if gp.max_abs_diff(gs) != 0.0 {
                return Err(format!("dK[{a}] differs at n={n} threads={nt}"));
            }
        }
        // the evaluation built on top must agree bit-for-bit too
        let y: Vec<f64> = t.iter().map(|&x| (0.41 * x).sin()).collect();
        let ev_s = ProfiledEval::from_cov(k_s, &y).unwrap();
        let ev_p = ProfiledEval::from_cov_with(k_p, &y, &ctx).unwrap();
        if ev_p.lnp != ev_s.lnp {
            return Err(format!("lnp differs at n={n} threads={nt}"));
        }
        Ok(())
    });
}
