//! Long-haul soak suite for the self-healing bounded-memory serving
//! lifecycle (grow → evict → refresh → retrain).
//!
//! The quick-mode tests below run in the tier-1 gate (ci.sh runs the
//! whole suite under `GPFAST_THREADS=1` *and* max, so the windowed
//! eviction/refresh path is exercised serially and threaded on every
//! merge). The `#[ignore]`d long-haul variant scales the window and
//! stream up; run it via `cargo test --release -- --ignored`.
//!
//! Invariants proven here (the issue's acceptance bar):
//!
//! * streaming **3× the window capacity** through a `WindowPolicy`
//!   session keeps every factor's dimension ≤ `max_points`, and at every
//!   step the windowed factor matches a **cold refit of the live
//!   window** to 1e-8 (lower triangle, logdet, σ̂_f², and predictions);
//! * a drift-injected session latches `needs_retrain()`, retrains **in
//!   place** (hot-swapping slots, evidence ranks and drift baselines
//!   without dropping the session), and the post-retrain log-scores
//!   recover;
//! * everything is deterministic under fixed seeds, for any thread
//!   budget.

use gpfast::coordinator::{
    DriftOptions, ModelSpec, PipelineConfig, ServeSession, Tournament, TrainOptions,
    WindowPolicy,
};
use gpfast::data::synthetic::table1_dataset;
use gpfast::gp::serve::Predictor;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;

/// Max |A − B| over the lower triangles (factor upper halves are
/// garbage by contract).
fn lower_diff(a: &gpfast::linalg::Matrix, b: &gpfast::linalg::Matrix) -> f64 {
    assert_eq!(a.rows(), b.rows());
    let mut d = 0.0f64;
    for i in 0..a.rows() {
        for j in 0..=i {
            d = d.max((a[(i, j)] - b[(i, j)]).abs());
        }
    }
    d
}

/// Assert one predictor's live windowed state matches a cold refit of
/// exactly the data it holds, at its own ϑ̂, to `tol`.
fn assert_matches_cold_refit(p: &Predictor, exec: &ExecutionContext, tol: f64, ctx_msg: &str) {
    let cold = p.refit_eval(exec).expect("cold refit of the live window");
    assert!(
        p.chol().dim() == cold.chol.dim(),
        "{ctx_msg}: dim {} vs cold {}",
        p.chol().dim(),
        cold.chol.dim()
    );
    let d = lower_diff(p.chol().factor_matrix(), cold.chol.factor_matrix());
    assert!(d < tol, "{ctx_msg}: windowed factor drifted {d:.3e} from the cold refit");
    let ld = (p.chol().logdet() - cold.chol.logdet()).abs();
    assert!(
        ld < tol * cold.chol.logdet().abs().max(1.0),
        "{ctx_msg}: logdet drifted {ld:.3e} ({} vs cold {})",
        p.chol().logdet(),
        cold.chol.logdet()
    );
    let ds = (p.sigma_f_hat2() - cold.sigma_f_hat2).abs();
    assert!(
        ds < tol * cold.sigma_f_hat2.max(1.0),
        "{ctx_msg}: σ̂_f² drifted {ds:.3e}"
    );
}

/// Train a 2-model tournament and wrap it in a windowed session.
fn windowed_session(
    n0: usize,
    max_points: usize,
    refresh_every: usize,
    exec: &ExecutionContext,
) -> ServeSession {
    let data = table1_dataset(n0, 0.1, 301);
    let mut cfg = PipelineConfig::fast();
    cfg.models = vec![ModelSpec::K1, ModelSpec::WendlandSe];
    cfg.train.multistart.restarts = 2;
    cfg.workers = 1;
    cfg.sigma_n = 0.1;
    cfg.exec = exec.clone();
    let mut rng = Xoshiro256::seed_from_u64(11);
    let result = Tournament::new(cfg).run(&data, &mut rng).expect("tournament");
    ServeSession::from_tournament(&result.models, &data, exec.clone())
        .expect("session")
        .with_window(WindowPolicy { max_points, refresh_every })
}

/// Deterministic synthetic stream continuing past the training grid.
fn stream_point(i: usize, t_last: f64) -> (f64, f64) {
    let t = t_last + 1.0 + i as f64;
    let y = 0.6 * (0.31 * t).sin() + 0.2 * (0.057 * t).cos();
    (t, y)
}

fn run_soak(n0: usize, max_points: usize, refresh_every: usize, check_all_every: usize) {
    let exec = ExecutionContext::from_env();
    let mut session = windowed_session(n0, max_points, refresh_every, &exec);
    let names: Vec<String> =
        session.model_names().iter().map(|s| s.to_string()).collect();
    let t_last = *session.predictor().t().last().unwrap();
    let steps = 3 * max_points;
    for i in 0..steps {
        let (t, y) = stream_point(i, t_last);
        session.observe(t, y).expect("windowed observe");
        // memory bound: no factor may ever exceed the window
        for name in &names {
            let p = session.model_predictor(name).expect("routed model");
            assert!(
                p.chol().dim() <= max_points,
                "step {i}: {name} factor dim {} > window {max_points}",
                p.chol().dim()
            );
            assert_eq!(p.chol().dim(), p.n(), "factor/data bookkeeping split");
        }
        // the winner's windowed factor ≡ cold refit of the live window,
        // at every step; all slots on a coarser cadence
        assert_matches_cold_refit(
            session.predictor(),
            &exec,
            1e-8,
            &format!("step {i} (winner)"),
        );
        if check_all_every > 0 && i % check_all_every == 0 {
            for name in &names {
                let p = session.model_predictor(name).unwrap();
                assert_matches_cold_refit(p, &exec, 1e-8, &format!("step {i} ({name})"));
            }
        }
        // every slot must hold exactly the same live window
        let w = session.predictor();
        for name in &names {
            let p = session.model_predictor(name).unwrap();
            assert_eq!(p.t(), w.t(), "step {i}: {name} window data diverged");
            assert_eq!(p.y(), w.y(), "step {i}: {name} window targets diverged");
        }
    }
    // after 3× capacity the window is full and slid well past the start
    let s = session.stats();
    assert_eq!(s.n_train, max_points);
    assert_eq!(s.observations_appended, steps);
    assert_eq!(s.observations_evicted as usize + max_points, n0 + steps);
    assert!(session.evictions() > 0);
    if refresh_every > 0 {
        assert!(
            session.refreshes() >= session.evictions() / refresh_every,
            "periodic refresh under-fired: {} refreshes for {} evictions",
            session.refreshes(),
            session.evictions()
        );
    }
    // and the windowed predictions equal a cold-refit predictor's
    let (wt, wy) = (session.predictor().t().to_vec(), session.predictor().y().to_vec());
    let theta = session.predictor().theta().to_vec();
    let cold = Predictor::fit(session.spec().build(session.sigma_n()), &wt, &wy, &theta, &exec)
        .expect("cold predictor");
    let t_probe: Vec<f64> = (0..16).map(|i| wt[wt.len() - 1] + 0.25 * (i + 1) as f64).collect();
    let served = session.predict(&t_probe);
    let refit = cold.predict_batch(&t_probe, &exec);
    for i in 0..t_probe.len() {
        assert!(
            (served.mean[i] - refit.mean[i]).abs() < 1e-8,
            "mean[{i}]: windowed {} vs refit {}",
            served.mean[i],
            refit.mean[i]
        );
        assert!(
            (served.sd[i] - refit.sd[i]).abs() < 1e-8,
            "sd[{i}]: windowed {} vs refit {}",
            served.sd[i],
            refit.sd[i]
        );
    }
}

/// Quick mode: the tier-1 soak. 3× a 48-point window through a 2-model
/// router, cold-refit check on the winner every step and on every slot
/// every 8 steps.
#[test]
fn soak_sliding_window_matches_cold_refit_for_3x_capacity() {
    run_soak(40, 48, 16, 8);
}

/// Long-haul mode: a 96-point window, 288 streamed points, every slot
/// checked at every step.
#[test]
#[ignore = "long-haul soak (minutes); quick mode runs in tier-1 — run via cargo test --release -- --ignored"]
fn soak_long_haul_large_window() {
    run_soak(80, 96, 24, 1);
}

/// The eviction path must be bit-identical across thread budgets: the
/// same windowed stream under a serial and a 4-thread session produces
/// byte-equal factors and predictions (ci.sh additionally runs the whole
/// suite under GPFAST_THREADS=1 and max).
#[test]
fn soak_windowed_stream_is_bit_identical_across_threads() {
    let run = |threads: usize| {
        let exec =
            if threads <= 1 { ExecutionContext::seq() } else { ExecutionContext::new(threads) };
        let mut session = windowed_session(30, 36, 8, &exec);
        let t_last = *session.predictor().t().last().unwrap();
        for i in 0..72 {
            let (t, y) = stream_point(i, t_last);
            session.observe(t, y).unwrap();
        }
        let probe: Vec<f64> = (0..8).map(|i| t_last + 80.0 + i as f64).collect();
        let pred = session.predict(&probe);
        let factor = session.predictor().chol().factor_matrix().clone();
        (pred.mean, pred.sd, factor, session.predictor().lnp())
    };
    let (m1, s1, f1, l1) = run(1);
    let (m4, s4, f4, l4) = run(4);
    assert_eq!(m1, m4, "windowed means diverge across thread budgets");
    assert_eq!(s1, s4, "windowed sds diverge across thread budgets");
    assert_eq!(l1, l4, "windowed lnp diverges across thread budgets");
    // compare lower triangles only (upper is garbage by contract)
    assert_eq!(lower_diff(&f1, &f4), 0.0, "windowed factors diverge across thread budgets");
}

/// Drift injection: stream a mean-shifted regime until the monitor
/// latches, retrain in place, and verify the hot swap heals the session
/// — scores recover, baselines reset, serving continues with counters
/// intact.
#[test]
fn soak_drift_injection_retrains_in_place_and_recovers() {
    let exec = ExecutionContext::from_env();
    let mut session = windowed_session(40, 64, 0, &exec)
        .with_drift_options(DriftOptions { window: 6, threshold: 2.0 });
    let t_last = *session.predictor().t().last().unwrap();
    // clean continuation fills baseline + recent windows: no flag
    let mut i = 0usize;
    for _ in 0..12 {
        let (t, y) = stream_point(i, t_last);
        session.observe(t, y).unwrap();
        i += 1;
    }
    assert!(!session.needs_retrain(), "clean continuation must not latch drift");
    // inject a +12 mean shift until the monitor latches
    let mut shifted = 0usize;
    while !session.needs_retrain() {
        let (t, y) = stream_point(i, t_last);
        session.observe(t, y + 12.0).unwrap();
        i += 1;
        shifted += 1;
        assert!(shifted <= 40, "drift monitor failed to latch after 40 shifted points");
    }
    let drifted_recent = session
        .drift()
        .iter()
        .filter_map(|d| d.recent)
        .fold(f64::INFINITY, f64::min);
    assert!(drifted_recent.is_finite());
    let appended_before = session.stats().observations_appended;
    let queries_before = session.stats().queries_served;

    // --- retrain in place on the current (shift-dominated) window
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 2;
    let mut rng = Xoshiro256::seed_from_u64(77);
    let outcome = session.retrain(&opts, 1, &mut rng).expect("retrain in place");
    assert_eq!(outcome.window_n, session.stats().n_train);
    assert_eq!(outcome.models.len(), 2);
    assert_eq!(outcome.winner, session.spec().name());
    for (_, _, new_ln_z) in &outcome.models {
        assert!(new_ln_z.is_finite());
    }
    // hot swap: latched flags cleared, baselines reset, session alive
    assert!(!session.needs_retrain(), "retrain must clear the latched drift flag");
    for d in session.drift() {
        assert!(d.baseline.is_none() && d.recent.is_none() && !d.drifted);
    }
    assert_eq!(session.stats().observations_appended, appended_before);
    assert_eq!(session.stats().queries_served, queries_before);
    // the retrained state is a genuine cold state of the window
    assert_matches_cold_refit(session.predictor(), &exec, 1e-8, "post-retrain");

    // --- post-retrain log-scores recover: score the next shifted points
    // against the retrained winner *before* absorbing them
    let mut recovered = Vec::new();
    for _ in 0..6 {
        let (t, y) = stream_point(i, t_last);
        let y = y + 12.0;
        recovered.push(session.predictor().log_predictive(t, y));
        session.observe(t, y).unwrap();
        i += 1;
    }
    let mean_recovered = recovered.iter().sum::<f64>() / recovered.len() as f64;
    assert!(
        mean_recovered > drifted_recent + 1.0,
        "post-retrain log-scores did not recover: {mean_recovered:.2} vs drifted {drifted_recent:.2}"
    );
    // continued shifted streaming against the retrained model forms a
    // clean new baseline — the monitor stays quiet
    for _ in 0..8 {
        let (t, y) = stream_point(i, t_last);
        session.observe(t, y + 12.0).unwrap();
        i += 1;
    }
    assert!(
        !session.needs_retrain(),
        "retrained session must not re-latch on the regime it was retrained for"
    );
}
