//! Integration tests for the model-comparison tournament and the
//! multi-model serving router:
//!
//! * on k₂-drawn data the tournament ranks k₂ above k₁ (ln B > 0) and
//!   the router serves k₂ by default;
//! * the warm-started child records fewer profiled-likelihood
//!   evaluations than a cold multistart of the same model;
//! * evidence-weighted model averaging collapses to the winner when
//!   ln B is large;
//! * the drift monitor flags retraining on mean-shifted appends and
//!   stays quiet on in-distribution streaming.

use gpfast::coordinator::{
    train_model, DriftOptions, ModelSpec, PipelineConfig, RouteMode, ServeSession, Tournament,
    TrainOptions,
};
use gpfast::data::synthetic::table1_dataset;
use gpfast::optimize::MultistartOptions;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;

/// The heavyweight end-to-end case: same data/seed regime as
/// `pipeline_end_to_end::k2_wins_decisively_with_more_data`, through the
/// tournament + router stack.
#[test]
fn tournament_ranks_k2_and_router_serves_it() {
    let data = table1_dataset(200, 0.1, 42);
    let mut cfg = PipelineConfig::paper_synthetic();
    cfg.train.multistart.restarts = 10;
    cfg.workers = 2;
    let exec = cfg.exec.clone();
    let mut rng = Xoshiro256::seed_from_u64(5);
    let result = Tournament::new(cfg.clone()).run(&data, &mut rng).unwrap();

    // --- ranking: the truth (k2) wins the Bayes factor, with error bars
    let k2 = result.model("k2").expect("k2 trained");
    let k1 = result.model("k1").expect("k1 trained");
    let lnb = k2.ln_z() - k1.ln_z();
    assert!(lnb > 0.0, "expected k2 (truth) to win at n=200, got ln B = {lnb}");
    assert_eq!(result.winner().name(), "k2");
    for tm in &result.models {
        assert_eq!(tm.evidence.sigma.len(), tm.train.theta_hat.len());
        assert!(tm.train.lnp_peak.is_finite());
    }
    // report mirrors the artifacts: ranked, ln_b column against winner
    assert_eq!(result.report.models[0].name, "k2");
    assert_eq!(result.report.models[0].ln_b, 0.0);
    assert!(result.report.models[1].ln_b < 0.0);

    // --- warm-start lineage: k2 inherited k1's peak and recorded fewer
    // profiled-likelihood evaluations than a cold multistart of k2
    assert!(k2.warm_started && !k1.warm_started);
    let mut cold_rng = Xoshiro256::seed_from_u64(91);
    let cold = train_model(
        &ModelSpec::K2,
        cfg.sigma_n,
        &data,
        &TrainOptions {
            multistart: MultistartOptions { restarts: 10, ..Default::default() },
            extra_starts: Vec::new(),
        },
        cfg.workers,
        &exec,
        &mut cold_rng,
    )
    .unwrap();
    assert!(
        k2.train.n_evals < cold.n_evals,
        "warm-started k2 used {} evals, cold multistart {}",
        k2.train.n_evals,
        cold.n_evals
    );
    // both found the same quality of peak
    assert!(
        k2.train.lnp_peak > cold.lnp_peak - 1.0,
        "warm peak {} must not be materially below cold peak {}",
        k2.train.lnp_peak,
        cold.lnp_peak
    );

    // --- routing: the session serves the evidence winner by default,
    // bit-identically to querying that model directly
    let session = ServeSession::from_tournament(&result.models, &data, exec.clone()).unwrap();
    assert_eq!(session.n_models(), 2);
    assert_eq!(session.spec(), &ModelSpec::K2);
    let t_star: Vec<f64> = (0..40).map(|i| 0.7 + 4.9 * i as f64).collect();
    let routed = session.predict(&t_star);
    let direct = session.predict_model("k2", &t_star).unwrap();
    assert_eq!(routed.mean, direct.mean, "winner routing must be the k2 predictor");
    assert_eq!(routed.sd, direct.sd);

    // --- evidence-weighted averaging: with ln B large the mixture
    // collapses to the winner; in general it brackets the two means
    let weights = session.weights();
    assert!((weights.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    assert!(weights[0] > weights[1], "winner must carry the larger weight");
    let averaged_session = ServeSession::from_tournament(&result.models, &data, exec.clone())
        .unwrap()
        .with_route(RouteMode::Averaged);
    let avg = averaged_session.predict(&t_star);
    let loser = session.predict_model("k1", &t_star).unwrap();
    for i in 0..t_star.len() {
        let (lo, hi) = if direct.mean[i] <= loser.mean[i] {
            (direct.mean[i], loser.mean[i])
        } else {
            (loser.mean[i], direct.mean[i])
        };
        assert!(
            avg.mean[i] >= lo - 1e-12 && avg.mean[i] <= hi + 1e-12,
            "mixture mean must sit between the component means at point {i}"
        );
    }
    if weights[1] < 1e-6 {
        for i in 0..t_star.len() {
            assert!(
                (avg.mean[i] - direct.mean[i]).abs() < 1e-4,
                "ln B = {lnb}: averaged mean {} vs winner {} at point {i}",
                avg.mean[i],
                direct.mean[i]
            );
            assert!((avg.sd[i] - direct.sd[i]).abs() < 1e-3);
        }
    }
}

/// The drift monitor: quiet on in-distribution streaming, latched on a
/// sustained mean shift, per model.
#[test]
fn drift_monitor_fires_on_mean_shifted_appends() {
    // 80 points from the synthetic truth; train on the first 60, stream
    // the genuine continuation, then a corrupted one
    let full = table1_dataset(80, 0.1, 1234);
    let head = full.head(60);
    let opts = TrainOptions {
        multistart: MultistartOptions { restarts: 2, ..Default::default() },
        extra_starts: Vec::new(),
    };
    let mut rng = Xoshiro256::seed_from_u64(17);
    let (session, _trained) = ServeSession::train_and_serve(
        &ModelSpec::K1,
        0.1,
        &head,
        &opts,
        1,
        ExecutionContext::seq(),
        &mut rng,
    )
    .unwrap();
    let mut session = session.with_drift_options(DriftOptions { window: 4, threshold: 3.0 });

    // in-distribution continuation: 4 baseline + 4 comparison points,
    // scored point-by-point against the growing factor
    session.observe_batch(&full.t[60..68], &full.y[60..68]).unwrap();
    let clean = session.drift();
    assert!(clean[0].baseline.is_some(), "baseline window must be full");
    assert!(clean[0].recent.is_some(), "recent window must be full");
    assert!(
        !session.needs_retrain(),
        "clean continuation flagged drift: deficit = {}",
        clean[0].deficit
    );

    // corrupted continuation: a 12-unit mean shift (~120 σ_n, and ≥10σ of
    // any plausible predictive sd, so even the first point's log-score
    // collapses by ≫ threshold before the factor adapts to the shift)
    let t_shift: Vec<f64> = (0..8).map(|i| full.t[67] + 1.0 + i as f64).collect();
    let y_shift: Vec<f64> = t_shift.iter().map(|&t| (t * 0.11).sin() + 12.0).collect();
    session.observe_batch(&t_shift, &y_shift).unwrap();
    let shifted = session.drift();
    let status = &shifted[0];
    assert!(
        session.needs_retrain(),
        "mean-shifted appends must flag retraining: deficit = {}",
        status.deficit
    );
    assert!(status.drifted);
    // note: the *current* deficit may have recovered — the factor absorbs
    // the shifted points and adapts — but the latch records that the
    // threshold was crossed, which is exactly the retrain signal
    // the session keeps serving (the flag is advisory)
    let p = session.predict(&[full.t[67] + 0.5]);
    assert!(p.mean[0].is_finite() && p.sd[0].is_finite());
}

/// Determinism: the tournament is reproducible from its seed (the
/// single-roster ≡ old-path bitwise claim is asserted in the
/// coordinator's unit tests; this is the end-to-end repeat).
#[test]
fn tournament_is_deterministic() {
    let data = table1_dataset(60, 0.1, 9);
    let mut cfg = PipelineConfig::fast();
    cfg.train.multistart.restarts = 3;
    let run = |seed: u64| {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tournament::new(cfg.clone()).run(&data, &mut rng).unwrap()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a.models.len(), b.models.len());
    for (ma, mb) in a.models.iter().zip(&b.models) {
        assert_eq!(ma.name(), mb.name());
        assert_eq!(ma.train.theta_hat, mb.train.theta_hat);
        assert_eq!(ma.train.lnp_peak, mb.train.lnp_peak);
        assert_eq!(ma.evidence.ln_z, mb.evidence.ln_z);
        assert_eq!(ma.train.n_evals, mb.train.n_evals);
    }
}
