//! Integration tests for the streaming prediction engine: the rank-1 /
//! bordered factor maintenance in `linalg`, the cached-factor batch
//! predictor in `gp::serve`, and the coordinator `ServeSession` — the
//! acceptance criteria of the serving-subsystem issue.

use gpfast::coordinator::{ModelSpec, ServeSession, TrainOptions};
use gpfast::data::tidal::{generate_tidal, TidalConfig};
use gpfast::gp::profiled::ProfiledEval;
use gpfast::gp::{predict, serve::Predictor};
use gpfast::kernels::{paper_k1, TIDAL_SIGMA_N};
use gpfast::linalg::{Chol, Matrix};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;

/// Random SPD matrix `A Aᵀ + n·I` (well-conditioned by construction).
fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            a[(i, j)] = rng.normal();
        }
    }
    let mut spd = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += a[(i, k)] * a[(j, k)];
            }
            spd[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
        }
    }
    spd
}

fn lower_diff(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.rows(), b.rows());
    let mut d = 0.0f64;
    for i in 0..a.rows() {
        for j in 0..=i {
            d = d.max((a[(i, j)] - b[(i, j)]).abs());
        }
    }
    d
}

/// Issue acceptance: L after k incremental extends is within 1e-10 of a
/// cold factorisation of the grown matrix.
#[test]
fn factor_after_k_extends_matches_cold_factorisation() {
    let mut rng = Xoshiro256::seed_from_u64(101);
    let (n0, k) = (120usize, 20usize);
    let big = random_spd(n0 + k, &mut rng);
    let mut lead = Matrix::zeros(n0, n0);
    for i in 0..n0 {
        for j in 0..n0 {
            lead[(i, j)] = big[(i, j)];
        }
    }
    let mut ch = Chol::factor(&lead).unwrap();
    for m in n0..n0 + k {
        let cross: Vec<f64> = (0..m).map(|i| big[(m, i)]).collect();
        ch.extend(&cross, big[(m, m)]).unwrap();
    }
    let cold = Chol::factor(&big).unwrap();
    let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
    assert!(d < 1e-10, "after {k} extends the factor drifted by {d:.3e}");
    assert!((ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs());
}

/// Issue acceptance: k rank-1 updates match a cold factorisation, and the
/// update → downdate round trip returns the original factor.
#[test]
fn repeated_rank1_updates_match_cold_and_round_trip() {
    let mut rng = Xoshiro256::seed_from_u64(103);
    let n = 100;
    let k = random_spd(n, &mut rng);
    let vs: Vec<Vec<f64>> =
        (0..6).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let orig = Chol::factor(&k).unwrap();
    let mut ch = orig.clone();
    let mut grown = k.clone();
    for v in &vs {
        let mut scratch = v.clone();
        ch.rank1_update(&mut scratch);
        for i in 0..n {
            for j in 0..n {
                grown[(i, j)] += v[i] * v[j];
            }
        }
    }
    let cold = Chol::factor(&grown).unwrap();
    let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
    assert!(d < 1e-10, "after {} updates the factor drifted by {d:.3e}", vs.len());
    // downdate in reverse order back to the original
    for v in vs.iter().rev() {
        let mut scratch = v.clone();
        ch.rank1_downdate(&mut scratch).unwrap();
    }
    let d = lower_diff(ch.factor_matrix(), orig.factor_matrix());
    assert!(d < 1e-10, "update→downdate round trip drifted by {d:.3e}");
    assert!((ch.logdet() - orig.logdet()).abs() < 1e-9 * orig.logdet().abs());
}

/// Issue acceptance: the streaming observe → predict loop matches a
/// from-scratch refit at the same hyperparameters to 1e-8, on the tidal
/// stream the serving layer was built for.
#[test]
fn streaming_tidal_predictions_match_from_scratch_refit() {
    let full = generate_tidal(&TidalConfig {
        n: 180,
        ..TidalConfig::six_lunar_months(2016)
    })
    .demean();
    // serve from physically sensible fixed hyperparameters (training is
    // exercised elsewhere; this isolates the serving math): T0 = e^4.5,
    // T1 = ln 12.42 h — the M2 tide. σ_n = 0.1 keeps κ(K̃) ~ 10³ so the
    // 1e-8 agreement bar sits orders of magnitude above rounding; the
    // serving machinery is identical at any σ_n.
    let sigma_n = 0.1;
    let theta = vec![4.5, 12.42f64.ln(), 0.0];
    let n0 = 120;
    let exec = ExecutionContext::seq();
    let mut predictor = Predictor::fit(
        paper_k1(sigma_n),
        &full.t[..n0],
        &full.y[..n0],
        &theta,
        &exec,
    )
    .unwrap();
    // stream the remaining 60 points in day-sized batches, serving a
    // batch of look-ahead queries after each
    let mut served_any = false;
    let mut m = n0;
    while m < full.t.len() {
        let hi = (m + 12).min(full.t.len());
        predictor.observe_batch(&full.t[m..hi], &full.y[m..hi]).unwrap();
        m = hi;
        let t_star: Vec<f64> =
            (0..8).map(|i| full.t[m - 1] + 0.5 + i as f64 * 0.5).collect();
        let served = predictor.predict_batch(&t_star, &exec);
        // cold refit at the same θ on exactly the data seen so far
        let model = paper_k1(sigma_n);
        let ev = ProfiledEval::from_cov(
            gpfast::gp::assemble_cov(&model, &full.t[..m], &theta),
            &full.y[..m],
        )
        .unwrap();
        let cold = predict(&model, &full.t[..m], &theta, &ev, &t_star);
        for i in 0..t_star.len() {
            assert!(
                (served.mean[i] - cold.mean[i]).abs() < 1e-8,
                "n={m} mean[{i}]: streamed {} vs refit {}",
                served.mean[i],
                cold.mean[i]
            );
            assert!(
                (served.sd[i] - cold.sd[i]).abs() < 1e-8,
                "n={m} sd[{i}]: streamed {} vs refit {}",
                served.sd[i],
                cold.sd[i]
            );
        }
        served_any = true;
    }
    assert!(served_any);
    let stats = predictor.stats();
    assert_eq!(stats.n_train, full.t.len());
    assert_eq!(stats.observations_appended, full.t.len() - n0);
}

/// Deletion property: evict ∘ extend round-trips. Appending a row and
/// deleting it restores the original factor; deleting the oldest row and
/// re-appending its data at the end matches a cold factorisation of the
/// cycled matrix (the sliding-window motion) — both ≤ 1e-10.
#[test]
fn evict_extend_round_trips_match_cold() {
    let mut rng = Xoshiro256::seed_from_u64(107);
    for &n in &[5usize, 40, 120] {
        let big = random_spd(n + 1, &mut rng);
        let mut lead = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                lead[(i, j)] = big[(i, j)];
            }
        }
        // extend ∘ evict: append the border row, delete it again
        let orig = Chol::factor(&lead).unwrap();
        let mut ch = orig.clone();
        let cross: Vec<f64> = (0..n).map(|i| big[(n, i)]).collect();
        ch.extend(&cross, big[(n, n)]).unwrap();
        ch.remove_row(n);
        let d = lower_diff(ch.factor_matrix(), orig.factor_matrix());
        assert!(d < 1e-10, "n={n}: extend→evict drifted {d:.3e}");
        assert!((ch.logdet() - orig.logdet()).abs() < 1e-9 * orig.logdet().abs());

        // evict ∘ extend: slide the window by one — drop row 0, append
        // a new trailing row; cold reference is the cycled matrix
        let mut ch = Chol::factor(&lead).unwrap();
        ch.remove_row(0);
        let cross: Vec<f64> = (1..n).map(|i| big[(n, i)]).collect();
        ch.extend(&cross, big[(n, n)]).unwrap();
        let mut cycled = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let (io, jo) = (if i < n - 1 { i + 1 } else { n }, if j < n - 1 { j + 1 } else { n });
                cycled[(i, j)] = big[(io, jo)];
            }
        }
        let cold = Chol::factor(&cycled).unwrap();
        let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
        assert!(d < 1e-10, "n={n}: evict→extend drifted {d:.3e} from the cold cycled factor");
        assert!((ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs());
    }
}

/// Deletion property: arbitrary-index `remove_row` equals a cold refit
/// of the matrix with that row/column struck out, on random SPD
/// matrices, including repeated deletions at mixed indices.
#[test]
fn arbitrary_index_remove_row_matches_refit() {
    let mut rng = Xoshiro256::seed_from_u64(109);
    for &n in &[6usize, 35, 100] {
        let k = random_spd(n, &mut rng);
        let mut ch = Chol::factor(&k).unwrap();
        // delete three rows at awkward indices, tracking the survivors
        let mut kept: Vec<usize> = (0..n).collect();
        for &del in &[0usize, n / 2, kept.len() - 3] {
            ch.remove_row(del);
            kept.remove(del);
        }
        let m = kept.len();
        let mut red = Matrix::zeros(m, m);
        for r in 0..m {
            for c in 0..m {
                red[(r, c)] = k[(kept[r], kept[c])];
            }
        }
        let cold = Chol::factor(&red).unwrap();
        let d = lower_diff(ch.factor_matrix(), cold.factor_matrix());
        assert!(d < 1e-10, "n={n}: 3-deletion factor drifted {d:.3e}");
        assert!(
            (ch.logdet() - cold.logdet()).abs() < 1e-9 * cold.logdet().abs().max(1.0),
            "n={n}: logdet {} vs {}",
            ch.logdet(),
            cold.logdet()
        );
    }
}

/// The eviction path is scalar and must be bit-identical for any thread
/// budget: the same evict/extend sequence under a serial and a
/// max-thread ExecutionContext yields byte-equal factors, α-state and
/// predictions (ci.sh runs the whole suite under GPFAST_THREADS=1 and
/// max on top of this in-process check).
#[test]
fn eviction_path_is_bit_identical_across_thread_budgets() {
    let run = |ctx: ExecutionContext| {
        let full = generate_tidal(&TidalConfig { n: 140, ..TidalConfig::six_lunar_months(5) })
            .demean();
        let theta = vec![4.5, 12.42f64.ln(), 0.0];
        let mut p =
            Predictor::fit(paper_k1(0.1), &full.t[..100], &full.y[..100], &theta, &ctx).unwrap();
        for i in 100..140 {
            p.observe(full.t[i], full.y[i]).unwrap();
            if p.n() > 110 {
                p.evict(0).unwrap();
            }
        }
        p.evict(17).unwrap();
        p.evict_front(3).unwrap();
        let probe: Vec<f64> = (0..24).map(|i| full.t[139] + 0.5 * (i + 1) as f64).collect();
        let pred = p.predict_batch(&probe, &ctx);
        (pred.mean, pred.sd, p.chol().factor_matrix().clone(), p.lnp(), p.sigma_f_hat2())
    };
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let (m1, s1, f1, l1, v1) = run(ExecutionContext::seq());
    let (mx, sx, fx, lx, vx) = run(ExecutionContext::new(threads.max(2)));
    assert_eq!(m1, mx, "eviction-path means diverge across thread budgets");
    assert_eq!(s1, sx, "eviction-path sds diverge across thread budgets");
    assert_eq!(l1, lx);
    assert_eq!(v1, vx);
    assert_eq!(lower_diff(&f1, &fx), 0.0, "eviction-path factors diverge");
}

/// The cached path and thread budget must not change results: a batch
/// through a ServeSession equals the pointwise eq.-2.1 reference for any
/// thread count.
#[test]
fn serve_session_batches_equal_pointwise_reference() {
    let data = generate_tidal(&TidalConfig { n: 96, ..TidalConfig::six_lunar_months(7) })
        .demean();
    let theta = vec![4.0, 12.42f64.ln(), 0.05];
    let model = paper_k1(TIDAL_SIGMA_N);
    let ev = ProfiledEval::from_cov(
        gpfast::gp::assemble_cov(&model, &data.t, &theta),
        &data.y,
    )
    .unwrap();
    // 500×96 cross-entries exceed the serve dispatch cutoff, so the
    // multi-thread rows genuinely run parallel here
    let t_star: Vec<f64> = (0..500).map(|i| 0.25 + i as f64 * 0.65).collect();
    let reference = predict(&model, &data.t, &theta, &ev, &t_star);
    for threads in [1usize, 2, 4] {
        let predictor = Predictor::fit(
            paper_k1(TIDAL_SIGMA_N),
            &data.t,
            &data.y,
            &theta,
            &ExecutionContext::seq(),
        )
        .unwrap();
        let out = predictor.predict_batch(&t_star, &ExecutionContext::new(threads));
        assert_eq!(out.mean, reference.mean, "threads={threads}");
        assert_eq!(out.sd, reference.sd, "threads={threads}");
    }
}

/// End-to-end coordinator wiring: train → serve → stream → serve, with
/// the session's predictions staying finite and its factor growing.
#[test]
fn serve_session_full_loop_on_synthetic_data() {
    let data = gpfast::data::synthetic::table1_dataset(60, 0.1, 77);
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 3;
    let mut rng = Xoshiro256::seed_from_u64(7);
    let (mut session, trained) = ServeSession::train_and_serve(
        &ModelSpec::K1,
        0.1,
        &data,
        &opts,
        2,
        ExecutionContext::new(2),
        &mut rng,
    )
    .unwrap();
    assert!(trained.lnp_peak.is_finite());
    let q1 = session.predict(&[10.5, 30.5, 61.0]);
    assert!(q1.mean.iter().all(|v| v.is_finite()));
    // stream five fresh points past the end of the grid
    let t_new: Vec<f64> = (1..=5).map(|i| 60.0 + i as f64).collect();
    let y_new: Vec<f64> = t_new.iter().map(|&t| (t * 0.3).sin() * 0.5).collect();
    session.observe_batch(&t_new, &y_new).unwrap();
    let q2 = session.predict(&[66.5]);
    assert!(q2.mean[0].is_finite() && q2.sd[0].is_finite());
    let s = session.stats();
    assert_eq!(s.n_train, 65);
    assert_eq!(s.observations_appended, 5);
    assert_eq!(s.queries_served, 4);
}
