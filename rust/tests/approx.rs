//! Integration suite for the approximate-inference tier (`gp::approx`):
//!
//! * SoD and FITC entrants train through the tournament, persist through
//!   the v-format artifact and serve through the router — with
//!   save → load → predict **bit-identical** to the in-memory predictor;
//! * a mixed exact/approximate roster (`k2, sod-k2, fitc-k2`) trains
//!   deterministically at 1 and 4 linalg threads, every entrant carrying
//!   a finite Laplace ln Z on the shared n-scale;
//! * the FITC predictive uncertainty is sane against the exact GP at the
//!   same hyperparameters (mean-level: an approximation must not claim
//!   materially more confidence than the exact posterior);
//! * on the regularly-gridded tidal series the Levinson value-only fast
//!   path reproduces the dense Cholesky profiled likelihood to 1e-8.

use std::path::PathBuf;

use gpfast::coordinator::{ModelSpec, PipelineConfig, ServeSession, Tournament, TrainedModel};
use gpfast::data::synthetic::{draw_gp_dataset, table1_dataset};
use gpfast::data::tidal::{generate_tidal, TidalConfig};
use gpfast::gp::approx::{self, ApproxKind};
use gpfast::gp::serve::Predictor;
use gpfast::gp::{profiled, ApproxKind as ReexportedKind};
use gpfast::kernels::{paper_k1, PaperK1};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gpfast_approx_{}_{tag}.bin", std::process::id()))
}

/// One mixed-roster tournament: the paper's exact k₂ plus its SoD and
/// FITC approximations, small restart budget.
fn mixed_tournament(threads: usize, seed: u64) -> (gpfast::data::Dataset, Vec<TrainedModel>) {
    let data = table1_dataset(80, 0.1, 42);
    let mut cfg = PipelineConfig::paper_synthetic();
    cfg.models = vec![ModelSpec::K2, ModelSpec::SodK2, ModelSpec::FitcK2];
    cfg.train.multistart.restarts = 2;
    cfg.workers = 2;
    cfg.exec = ExecutionContext::new(threads);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let result = Tournament::new(cfg).run(&data, &mut rng).unwrap();
    (data, result.models)
}

/// The tentpole acceptance: the mixed roster trains through one
/// tournament, every entrant gets a Laplace ln Z on the same n-scale,
/// the reduced factors have the spec-mandated dimensions, and the winner
/// serves through a `ServeSession`.
#[test]
fn mixed_roster_trains_ranks_and_serves() {
    let (data, models) = mixed_tournament(1, 7);
    assert_eq!(models.len(), 3);
    let n = data.len();
    for tm in &models {
        assert!(
            tm.ln_z().is_finite(),
            "{}: ln Z = {} must be finite on the shared scale",
            tm.spec.name(),
            tm.ln_z()
        );
        assert!(tm.train.lnp_peak.is_finite());
        assert_eq!(
            tm.train.peak_eval.chol.dim(),
            tm.spec.factor_dim(n),
            "{}: reduced factor dimension",
            tm.spec.name()
        );
        assert_eq!(tm.train.peak_eval.alpha.len(), tm.spec.factor_dim(n));
    }
    // the reduced dims really are reduced
    let sod = models.iter().find(|m| m.spec == ModelSpec::SodK2).unwrap();
    let fitc = models.iter().find(|m| m.spec == ModelSpec::FitcK2).unwrap();
    assert_eq!(sod.train.peak_eval.chol.dim(), approx::sod_m(n));
    assert_eq!(fitc.train.peak_eval.chol.dim(), approx::fitc_m(n));
    assert!(approx::sod_m(n) < n && approx::fitc_m(n) < n);

    // the ranked set serves through the router, winner by default
    let session =
        ServeSession::from_tournament(&models, &data, ExecutionContext::seq()).unwrap();
    assert_eq!(session.n_models(), 3);
    let t_star: Vec<f64> = (0..24).map(|q| 0.4 + 3.3 * q as f64).collect();
    let routed = session.predict(&t_star);
    assert!(routed.mean.iter().all(|v| v.is_finite()));
    assert!(routed.sd.iter().all(|v| v.is_finite() && *v > 0.0));
    // every entrant is individually queryable through the same session
    for name in ["k2", "sod-k2", "fitc-k2"] {
        let p = session.predict_model(name, &t_star).unwrap();
        assert!(p.mean.iter().all(|v| v.is_finite()), "{name}");
        assert!(p.sd.iter().all(|v| v.is_finite() && *v > 0.0), "{name}");
    }
}

/// Save → load → predict round-trips bit-identically for both
/// approximate backends (the artifact layer's `spec.factor_dim`
/// relaxation at work), and a session restored from the artifacts serves
/// the same bits as the in-memory one.
#[test]
fn approx_artifacts_round_trip_bit_identically() {
    let (data, models) = mixed_tournament(1, 9);
    let exec = ExecutionContext::seq();
    let t_star: Vec<f64> = (0..32).map(|q| 0.9 + 2.45 * q as f64).collect();
    let mut paths = Vec::new();
    for tm in &models {
        let name = tm.spec.name();
        let path = tmp_path(name);
        tm.save(&path, &data).expect("save");
        let (tm2, data2) = TrainedModel::load(&path).expect("load");
        assert_eq!(tm2.spec, tm.spec, "{name}");
        assert_eq!(tm2.train.theta_hat, tm.train.theta_hat, "{name}");
        assert_eq!(tm2.train.peak_eval.alpha, tm.train.peak_eval.alpha, "{name}");
        assert_eq!(
            tm2.train.peak_eval.chol.logdet(),
            tm.train.peak_eval.chol.logdet(),
            "{name}"
        );
        let p_mem = tm.predictor(&data).expect("in-memory predictor");
        let p_disk = tm2.predictor(&data2).expect("reloaded predictor");
        assert_eq!(p_mem.n(), tm.spec.factor_dim(data.len()), "{name}: serving size");
        let a = p_mem.predict_batch(&t_star, &exec);
        let b = p_disk.predict_batch(&t_star, &exec);
        assert_eq!(a.mean, b.mean, "{name}: reloaded means must be bit-identical");
        assert_eq!(a.sd, b.sd, "{name}: reloaded sds must be bit-identical");
        paths.push(path);
    }
    // a full session restored from the three artifacts serves the same
    // bits as the in-memory router
    let mem = ServeSession::from_tournament(&models, &data, ExecutionContext::seq()).unwrap();
    let want = mem.predict(&t_star);
    let path_refs: Vec<&std::path::Path> = paths.iter().map(|p| p.as_path()).collect();
    let restored =
        ServeSession::from_artifacts(&path_refs, ExecutionContext::seq()).unwrap();
    assert_eq!(restored.n_models(), 3);
    assert_eq!(restored.spec().name(), mem.spec().name());
    let got = restored.predict(&t_star);
    assert_eq!(got.mean, want.mean);
    assert_eq!(got.sd, want.sd);
    for p in paths {
        let _ = std::fs::remove_file(p);
    }
}

/// The mixed roster is deterministic in the thread count: 1-thread and
/// 4-thread tournaments (same seed) produce bitwise-identical peaks and
/// evidences for every entrant, exact and approximate alike.
#[test]
fn mixed_roster_is_deterministic_across_thread_counts() {
    let (_, seq) = mixed_tournament(1, 7);
    let (_, par) = mixed_tournament(4, 7);
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        let name = a.spec.name();
        assert_eq!(a.spec, b.spec, "ranking order must match");
        assert_eq!(a.train.theta_hat, b.train.theta_hat, "{name}: θ̂");
        assert_eq!(
            a.train.lnp_peak.to_bits(),
            b.train.lnp_peak.to_bits(),
            "{name}: lnp_peak"
        );
        assert_eq!(a.ln_z().to_bits(), b.ln_z().to_bits(), "{name}: ln Z");
        assert_eq!(a.train.peak_eval.alpha, b.train.peak_eval.alpha, "{name}: α");
    }
}

/// Sanity bound on the FITC uncertainty: at the *same* hyperparameters,
/// the approximate posterior must not be materially more confident than
/// the exact one on held-out query points (mean level, 5% slack for the
/// independently-profiled σ̂_f scales).
#[test]
fn fitc_predictive_sd_is_not_overconfident() {
    let model = paper_k1(0.1);
    let mut rng = Xoshiro256::seed_from_u64(2024);
    let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 200, &mut rng);
    let theta = PaperK1::truth();
    let ctx = ExecutionContext::seq();

    let exact = Predictor::fit(model.clone(), &data.t, &data.y, &theta, &ctx).unwrap();
    let ev = approx::peak_eval_with(ApproxKind::Fitc, &model, &data.t, &data.y, &theta, &ctx)
        .unwrap();
    let (u, y_pseudo) = approx::serve_parts(ApproxKind::Fitc, &data.t, &data.y, &ev);
    let fitc = Predictor::from_eval(model, u, y_pseudo, theta.to_vec(), ev);

    let t_star: Vec<f64> = (0..80).map(|q| 0.37 + 2.41 * q as f64).collect();
    let pe = exact.predict_batch(&t_star, &ctx);
    let pf = fitc.predict_batch(&t_star, &ctx);
    // normalise out the profiled scales so the comparison is purely about
    // the posterior information content
    let se = exact.sigma_f_hat2().sqrt();
    let sf = fitc.sigma_f_hat2().sqrt();
    let mean_exact = pe.sd.iter().map(|v| v / se).sum::<f64>() / t_star.len() as f64;
    let mean_fitc = pf.sd.iter().map(|v| v / sf).sum::<f64>() / t_star.len() as f64;
    assert!(
        mean_fitc >= 0.95 * mean_exact,
        "FITC mean sd {mean_fitc:.6} vs exact {mean_exact:.6}: the approximation \
         claims more confidence than the exact posterior"
    );
}

/// The re-exported kind and the module path name the same type (doc-level
/// API check), and the SoD serving subset really is a subset of the data.
#[test]
fn sod_serves_a_true_subset_of_the_data() {
    let model = paper_k1(0.1);
    let mut rng = Xoshiro256::seed_from_u64(11);
    let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 60, &mut rng);
    let theta = PaperK1::truth();
    let ctx = ExecutionContext::seq();
    let kind: ReexportedKind = ApproxKind::Sod;
    let ev = approx::peak_eval_with(kind, &model, &data.t, &data.y, &theta, &ctx).unwrap();
    let (ts, ys) = approx::serve_parts(kind, &data.t, &data.y, &ev);
    assert_eq!(ts.len(), approx::sod_m(60));
    for (tv, yv) in ts.iter().zip(&ys) {
        let i = data.t.iter().position(|v| v == tv).expect("subset time not in data");
        assert_eq!(data.y[i], *yv, "subset target must be the raw observation");
    }
}

/// §3(b) footnote 7, closed: on the exactly-regular tidal grid
/// (t_k = 2k hours) the Levinson value-only fast path must reproduce the
/// dense Cholesky profiled likelihood to 1e-8 relative — and must
/// actually have taken the Toeplitz route (hit counter).
#[test]
fn toeplitz_fast_path_matches_cholesky_on_tidal_grid() {
    let data = generate_tidal(&TidalConfig::six_lunar_months(20160125)).demean();
    assert_eq!(data.len(), 1968);
    // tidal-scale k₁: ~150 h compact support, the 12.42 h lunar period
    let model = paper_k1(0.1);
    let theta = vec![150f64.ln(), 12.42f64.ln(), 0.0];
    let ctx = ExecutionContext::seq();
    // per-thread snapshot: the sequential context keeps the evaluation on
    // this thread, so the delta is immune to concurrent test binaries
    let snap = profiled::CounterSnapshot::take();
    let fast = profiled::eval_value_with(&model, &data.t, &data.y, &theta, &ctx).unwrap();
    assert!(
        snap.delta().toeplitz_hits > 0,
        "uniform 2-hour cadence must route through Levinson"
    );
    let dense = profiled::eval_with(&model, &data.t, &data.y, &theta, &ctx).unwrap().lnp;
    let rel = (fast - dense).abs() / dense.abs().max(1.0);
    assert!(rel < 1e-8, "fast {fast} vs dense {dense} (rel {rel:.3e})");
}
