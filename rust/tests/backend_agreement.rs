//! Integration test: the XLA artifact backend and the native backend must
//! produce identical covariance matrices, gradients, and profiled
//! likelihoods — this is the end-to-end proof that L1 (Pallas kernel),
//! L2 (jax graph) and L3 (rust coordinator) compute the same math.
//!
//! Skips (with a message) when `artifacts/` has not been built yet; the
//! Makefile `test` target builds artifacts first, so CI always runs it.
//! The whole file is gated on the `xla` feature (the offline image has no
//! PJRT FFI crate).
#![cfg(feature = "xla")]

use gpfast::gp::profiled::ProfiledEval;
use gpfast::kernels::{paper_k1, paper_k2, PaperK1, PaperK2};
use gpfast::runtime::{Backend, NativeBackend, XlaBackend};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

fn grid(n: usize) -> Vec<f64> {
    (1..=n).map(|i| i as f64).collect()
}

#[test]
fn xla_and_native_covariance_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::load(&dir).expect("loading artifacts");
    let mut native = NativeBackend::new();
    let t = grid(30);
    for (model, theta) in [
        (paper_k1(0.1), PaperK1::truth()),
        (paper_k2(0.1), PaperK2::truth()),
    ] {
        if !xla.accelerates(&model, t.len()) {
            eprintln!("no n=30 artifact for {}, skipping", model.name);
            continue;
        }
        let k_x = xla.cov(&model, &t, &theta).unwrap();
        let k_n = native.cov(&model, &t, &theta).unwrap();
        let d = k_x.max_abs_diff(&k_n);
        assert!(d < 1e-12, "{}: cov diff {d:.3e}", model.name);

        let (k_x2, g_x) = xla.cov_and_grads(&model, &t, &theta).unwrap();
        let (k_n2, g_n) = native.cov_and_grads(&model, &t, &theta).unwrap();
        assert!(k_x2.max_abs_diff(&k_n2) < 1e-12);
        assert_eq!(g_x.len(), g_n.len());
        for (a, (gx, gn)) in g_x.iter().zip(&g_n).enumerate() {
            let d = gx.max_abs_diff(gn);
            assert!(d < 1e-12, "{} grad[{a}] diff {d:.3e}", model.name);
        }
        assert!(xla.n_xla > 0);
    }
}

#[test]
fn xla_full_lnp_matches_rust_profiled_eval() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::load(&dir).expect("loading artifacts");
    let t = grid(30);
    // deterministic pseudo-data
    let y: Vec<f64> = (0..30).map(|i| ((i as f64) * 0.7).sin() * 1.3).collect();
    for (model, theta) in [
        (paper_k1(0.1), PaperK1::truth()),
        (paper_k2(0.1), PaperK2::truth()),
    ] {
        let Some((lnp_x, s2_x, logdet_x)) =
            xla.full_lnp(&model, &t, &y, &theta).expect("full_lnp execution")
        else {
            eprintln!("no full_lnp artifact for {}, skipping", model.name);
            continue;
        };
        // rust native: assemble + factor + profile
        let k = gpfast::gp::assemble_cov(&model, &t, &theta);
        let ev = ProfiledEval::from_cov(k, &y).unwrap();
        assert!(
            (lnp_x - ev.lnp).abs() < 1e-8 * ev.lnp.abs(),
            "{}: lnp {lnp_x} vs {}",
            model.name,
            ev.lnp
        );
        assert!((s2_x - ev.sigma_f_hat2).abs() < 1e-9 * ev.sigma_f_hat2);
        assert!((logdet_x - ev.chol.logdet()).abs() < 1e-8 * ev.chol.logdet().abs());
    }
}

#[test]
fn strict_mode_errors_on_missing_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::load(&dir).expect("loading artifacts");
    xla.strict = true;
    let model = paper_k1(0.1);
    let t = grid(17); // no artifact for n=17
    assert!(xla.cov(&model, &t, &PaperK1::truth()).is_err());
    xla.strict = false;
    assert!(xla.cov(&model, &t, &PaperK1::truth()).is_ok());
    assert_eq!(xla.n_fallback, 1);
}
