//! Fault-injected recovery soak for the numerical-health tier
//! (grow → evict → refresh → retrain → **quarantine**).
//!
//! A deterministic [`FaultPlan`] corrupts a windowed observation stream
//! with near-duplicate inputs, huge outliers and non-finite values, and
//! the suite proves the serving acceptance bar:
//!
//! * the session **never panics** and **never serves a non-finite
//!   prediction** — every step either absorbs the point, or reports a
//!   recoverable error (non-finite boundary rejection, no-slot-can-
//!   absorb), or quarantines a slot and keeps serving;
//! * non-finite points are rejected with **zero** state change;
//! * quarantined slots are routed around (Winner falls to the
//!   next-ranked healthy slot, Averaged renormalises) and **re-enter**
//!   after a successful retrain;
//! * the clean-data control arm is bit-identical to streaming without
//!   the fault plan, with **zero** jitter-ladder rungs taken (recorded
//!   jitter = 0 on every slot) and zero health events;
//! * corrupt artifact bytes fail hydration cleanly and a session
//!   restarts from the surviving artifacts.
//!
//! ci.sh runs this suite under `GPFAST_THREADS=1` *and* max.

use std::path::PathBuf;

use gpfast::coordinator::{
    DriftOptions, Fault, FaultPlan, ModelSpec, PipelineConfig, RouteMode, ServeSession,
    Tournament, TrainOptions, TrainedModel, WindowPolicy,
};
use gpfast::data::synthetic::table1_dataset;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;

/// Train a 2-model tournament and wrap it in a windowed session (the
/// soak_serving.rs topology with its own seeds).
fn windowed_session(
    n0: usize,
    max_points: usize,
    refresh_every: usize,
    exec: &ExecutionContext,
) -> ServeSession {
    let data = table1_dataset(n0, 0.1, 401);
    let mut cfg = PipelineConfig::fast();
    cfg.models = vec![ModelSpec::K1, ModelSpec::WendlandSe];
    cfg.train.multistart.restarts = 2;
    cfg.workers = 1;
    cfg.sigma_n = 0.1;
    cfg.exec = exec.clone();
    let mut rng = Xoshiro256::seed_from_u64(19);
    let result = Tournament::new(cfg).run(&data, &mut rng).expect("tournament");
    ServeSession::from_tournament(&result.models, &data, exec.clone())
        .expect("session")
        .with_window(WindowPolicy { max_points, refresh_every })
}

/// Deterministic synthetic stream continuing past the training grid.
fn stream_point(i: usize, t_last: f64) -> (f64, f64) {
    let t = t_last + 1.0 + i as f64;
    let y = 0.6 * (0.31 * t).sin() + 0.2 * (0.057 * t).cos();
    (t, y)
}

/// Every value the session is holding or serving must be finite.
fn assert_session_finite(session: &ServeSession, ctx: &str) {
    for name in session.model_names() {
        let p = session.model_predictor(name).expect("routed model");
        assert!(
            p.t().iter().chain(p.y()).all(|v| v.is_finite()),
            "{ctx}: {name} holds non-finite window data"
        );
    }
    for h in session.health() {
        assert!(!h.cond_est.is_nan(), "{ctx}: {} cond estimate is NaN", h.model);
        assert!(h.jitter.is_finite() && h.jitter >= 0.0, "{ctx}: bad jitter {}", h.jitter);
    }
}

/// The core soak: a corrupted stream through a windowed 2-model router.
/// Quick mode (tier-1) streams 3× the window; the `#[ignore]`d long-haul
/// variant scales up.
fn run_fault_soak(n0: usize, max_points: usize, refresh_every: usize) {
    let exec = ExecutionContext::from_env();
    let mut session = windowed_session(n0, max_points, refresh_every, &exec)
        .with_drift_options(DriftOptions { window: 4, threshold: 2.0 });
    // outliers at ±50 — ~60× the signal amplitude, more than enough to
    // crater every windowed log-score and latch drift, while keeping the
    // post-fault retrain on the outlier-laden window well conditioned
    // (the default ±1e7 scale is exercised by the FaultPlan unit tests)
    let plan = FaultPlan { outlier_scale: 50.0, ..FaultPlan::soak_default() };
    let t_last = *session.predictor().t().last().unwrap();
    let mut t_prev = t_last;
    let steps = 3 * max_points;
    let mut absorbed = 0usize;
    let mut rejected = 0usize;
    for i in 0..steps {
        let (t_nom, y_nom) = stream_point(i, t_last);
        let (t, y, fault) = plan.apply(i, t_nom, y_nom, t_prev);
        let n_before = session.stats().n_train;
        let appended_before = session.stats().observations_appended;
        match session.observe(t, y) {
            Ok(()) => {
                absorbed += 1;
                assert!(
                    fault != Fault::NonFinite,
                    "step {i}: non-finite point crossed the data boundary"
                );
                t_prev = t;
            }
            Err(e) => {
                rejected += 1;
                let msg = format!("{e:#}");
                assert!(!msg.is_empty(), "step {i}: empty error");
                match fault {
                    Fault::NonFinite => {
                        assert!(msg.contains("non-finite"), "step {i}: {msg}");
                        // boundary rejection is a zero-state-change event
                        assert_eq!(session.stats().n_train, n_before, "step {i}");
                        assert_eq!(
                            session.stats().observations_appended,
                            appended_before,
                            "step {i}: rejected point was appended"
                        );
                    }
                    Fault::NearDuplicate => {} // reject or quarantine: both legal
                    Fault::Clean | Fault::Outlier => {
                        panic!("step {i}: benign {fault:?} point rejected: {msg}")
                    }
                }
            }
        }
        // the serving invariant, every single step: finite predictions
        // from whatever the session now holds
        let p = session.predict(&[t_nom + 0.5, t_nom + 7.25]);
        assert!(
            p.mean.iter().chain(&p.sd).all(|v| v.is_finite()),
            "step {i}: non-finite prediction served"
        );
        assert_session_finite(&session, &format!("step {i}"));
        // the memory bound holds through every fault
        assert!(session.stats().n_train <= max_points.max(n0));
    }
    assert!(absorbed > steps / 2, "only {absorbed}/{steps} points absorbed");
    assert!(rejected > 0, "the fault plan never exercised a rejection");
    // the outliers crater the windowed log-scores: the drift monitor
    // (or a health latch) must be demanding a retrain by now
    assert!(session.needs_retrain(), "a faulted stream must latch needs_retrain");

    // --- recovery: retrain in place on the (outlier-laden) window
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 2;
    let mut rng = Xoshiro256::seed_from_u64(83);
    let outcome = session.retrain(&opts, 1, &mut rng).expect("retrain on faulted window");
    assert!(outcome.models.iter().all(|(_, _, z)| z.is_finite()));
    assert_eq!(session.n_quarantined(), 0, "retrain must re-enter every quarantined slot");
    assert!(!session.needs_retrain(), "retrain must clear drift and health latches");
    assert_session_finite(&session, "post-retrain");
    // and the healed session keeps absorbing clean points
    for j in 0..8 {
        let (t, y) = stream_point(steps + j, t_last);
        session.observe(t, y).expect("post-retrain clean observe");
    }
    let p = session.predict(&[t_last + steps as f64 + 12.5]);
    assert!(p.mean[0].is_finite() && p.sd[0].is_finite());
}

/// Quick mode: the tier-1 fault soak (ci.sh runs it serial and threaded).
#[test]
fn soak_faulted_stream_recovers_quick() {
    run_fault_soak(32, 40, 8);
}

/// Long-haul mode: larger window, 3× the stream.
#[test]
#[ignore = "long-haul fault soak (minutes); quick mode runs in tier-1 — run via cargo test --release -- --ignored"]
fn soak_faulted_stream_recovers_long_haul() {
    run_fault_soak(64, 96, 16);
}

/// The clean control arm: a `FaultPlan::clean()` stream is bit-identical
/// to streaming the raw points, takes zero jitter-ladder rungs, and
/// logs zero health events — the robustness tier is free on clean data.
#[test]
fn clean_control_arm_is_bit_identical_with_zero_jitter() {
    let exec = ExecutionContext::from_env();
    let run = |through_plan: bool| {
        let mut session = windowed_session(30, 36, 8, &exec);
        let plan = FaultPlan::clean();
        let t_last = *session.predictor().t().last().unwrap();
        let mut t_prev = t_last;
        for i in 0..72 {
            let (t_nom, y_nom) = stream_point(i, t_last);
            let (t, y) = if through_plan {
                let (t, y, f) = plan.apply(i, t_nom, y_nom, t_prev);
                assert_eq!(f, Fault::Clean);
                (t, y)
            } else {
                (t_nom, y_nom)
            };
            session.observe(t, y).expect("clean observe");
            t_prev = t;
        }
        let probe: Vec<f64> = (0..8).map(|q| t_last + 80.0 + q as f64).collect();
        let pred = session.predict(&probe);
        // zero rungs taken, zero health events, nothing quarantined
        for h in session.health() {
            assert_eq!(h.jitter, 0.0, "{}: clean data took a jitter rung", h.model);
            assert_eq!(h.downdate_failures, 0, "{}", h.model);
            assert!(!h.degraded && !h.quarantined, "{}", h.model);
            assert!(h.cond_est.is_finite() && h.cond_est >= 1.0);
        }
        assert_eq!(session.n_quarantined(), 0);
        assert!(!session.needs_retrain());
        (pred.mean, pred.sd, session.predictor().lnp())
    };
    let (m_raw, s_raw, l_raw) = run(false);
    let (m_plan, s_plan, l_plan) = run(true);
    assert_eq!(m_raw, m_plan, "clean plan changed served means");
    assert_eq!(s_raw, s_plan, "clean plan changed served sds");
    assert_eq!(l_raw, l_plan, "clean plan changed the maintained lnp");
}

/// Forced quarantine end-to-end: the winner is routed around under both
/// route modes, freezes while healthy slots absorb, and re-enters after
/// retrain with the roster windows re-synchronised.
#[test]
fn quarantined_winner_is_routed_around_and_reenters_after_retrain() {
    let exec = ExecutionContext::from_env();
    let mut session = windowed_session(28, 64, 0, &exec);
    assert_eq!(session.n_models(), 2);
    let t_last = *session.predictor().t().last().unwrap();
    let names: Vec<&str> = session.model_names();
    let (winner, runner_up) = (names[0], names[1]);
    let probe = [29.5, 33.25, 41.0];
    let runner_pred = session
        .model_predictor(runner_up)
        .unwrap()
        .predict_batch(&probe, &exec);

    assert!(session.quarantine_model(winner), "winner must be quarantinable");
    assert!(!session.quarantine_model("no-such-model"));
    assert_eq!(session.n_quarantined(), 1);
    assert!(session.needs_retrain(), "quarantine must latch the retrain signal");
    assert!(session.health()[0].quarantined && !session.health()[1].quarantined);
    // Winner mode falls to the next-ranked healthy slot, bit for bit
    let served = session.predict(&probe);
    assert_eq!(served.mean, runner_pred.mean, "winner route must fall to the runner-up");
    assert_eq!(served.sd, runner_pred.sd);
    // Averaged mode renormalises: all weight on the healthy slot
    let w = session.weights();
    assert_eq!(w[0], 0.0);
    assert_eq!(w[1], 1.0);
    let avg_session = session.with_route(RouteMode::Averaged);
    let avg = avg_session.predict(&probe);
    for i in 0..probe.len() {
        assert!((avg.mean[i] - runner_pred.mean[i]).abs() < 1e-12);
        assert!((avg.sd[i] - runner_pred.sd[i]).abs() < 1e-9);
    }
    session = avg_session.with_route(RouteMode::Winner);

    // streaming continues: the healthy slot absorbs, the quarantined
    // slot freezes at its last good window
    let frozen_n = session.model_predictor(winner).unwrap().n();
    for i in 0..5 {
        let (t, y) = stream_point(i, t_last);
        session.observe(t, y).expect("healthy slot must keep absorbing");
    }
    assert_eq!(session.model_predictor(winner).unwrap().n(), frozen_n, "frozen slot grew");
    assert_eq!(session.model_predictor(runner_up).unwrap().n(), frozen_n + 5);

    // retrain re-enters the quarantined model on the healthy window
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 2;
    let mut rng = Xoshiro256::seed_from_u64(89);
    let outcome = session.retrain(&opts, 1, &mut rng).expect("re-entry retrain");
    assert_eq!(outcome.window_n, frozen_n + 5, "retrain must use the healthy window");
    assert_eq!(session.n_quarantined(), 0);
    assert!(!session.needs_retrain());
    for h in session.health() {
        assert!(!h.quarantined && !h.degraded);
    }
    // the roster windows are re-synchronised and both slots serve again
    let a = session.model_predictor(names[0]).unwrap();
    let b = session.model_predictor(names[1]).unwrap();
    assert_eq!(a.t(), b.t(), "post-retrain windows diverged");
    assert_eq!(a.n(), frozen_n + 5);
    let w = session.weights();
    assert!(w.iter().all(|&x| x > 0.0), "re-entered roster must share weight: {w:?}");
    let p = session.predict(&[40.5]);
    assert!(p.mean[0].is_finite() && p.sd[0].is_finite());
}

/// Duplicate-timestamp regression: a [`FaultPlan`] with a **zero**
/// near-duplicate offset injects *exact* duplicates, and a tiny window
/// (`max_points = 2`) lets them crowd every distinct point out. The
/// resulting all-coincident window used to **panic** inside
/// `DataSpan::from_times` when a retrain was attempted; it must now
/// surface as a recoverable error that leaves the session fully
/// serviceable — and a single distinct observation must make the next
/// retrain succeed.
#[test]
fn exact_duplicate_window_fails_retrain_cleanly_instead_of_panicking() {
    let exec = ExecutionContext::seq();
    let mut session = windowed_session(24, 2, 0, &exec);
    let plan = FaultPlan {
        near_dup_every: 1,
        outlier_every: 0,
        non_finite_every: 0,
        outlier_scale: 0.0,
        near_dup_offset: 0.0, // exact duplicates, not near ones
    };
    let t_last = *session.predictor().t().last().unwrap();
    let mut t_prev = t_last;
    for i in 0..4 {
        let (t_nom, y_nom) = stream_point(i, t_last);
        let (t, y, fault) = plan.apply(i, t_nom, y_nom, t_prev);
        if i > 0 {
            assert_eq!(fault, Fault::NearDuplicate);
            assert_eq!(t, t_prev, "offset-0 plan must inject exact duplicates");
        }
        // σ_n keeps the extension pivot positive even for an exact
        // duplicate input, so the point absorbs rather than rejects
        session.observe(t, y).expect("duplicate absorbs through the noise floor");
        t_prev = t;
    }
    // the window now holds two coincident timestamps
    let w = session.predictor().t().to_vec();
    assert_eq!(w.len(), 2);
    assert_eq!(w[0], w[1], "window should have degenerated to duplicates");
    // retrain on the degenerate window: a clean error, not a panic, and
    // zero session damage
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 2;
    let mut rng = Xoshiro256::seed_from_u64(97);
    let err = session.retrain(&opts, 1, &mut rng).expect_err("degenerate window must error");
    assert!(
        format!("{err:#}").contains("degenerate input grid"),
        "unexpected error: {err:#}"
    );
    let p = session.predict(&[w[0] + 0.5]);
    assert!(p.mean[0].is_finite() && p.sd[0].is_finite(), "session must keep serving");
    // one distinct point heals the window and the retrain goes through
    session.observe(w[0] + 3.0, 0.1).expect("distinct point absorbs");
    let outcome = session.retrain(&opts, 1, &mut rng).expect("healed window retrains");
    assert_eq!(outcome.window_n, 2);
    assert!(outcome.models.iter().all(|(_, _, z)| z.is_finite()));
}

/// Locate the little-endian byte pattern of a known f64 in an artifact.
fn find_f64(hay: &[u8], v: f64) -> usize {
    let pat = v.to_le_bytes();
    hay.windows(8).position(|w| w == pat).expect("known f64 not found in artifact bytes")
}

/// Corrupt-artifact hydration fault: a poisoned file fails cleanly, the
/// roster restarts from the surviving artifact, and the restarted
/// session serves finite predictions.
#[test]
fn corrupt_artifact_hydration_fails_cleanly_and_session_restarts_from_survivor() {
    let exec = ExecutionContext::seq();
    let data = table1_dataset(24, 0.1, 419);
    let mut cfg = PipelineConfig::fast();
    cfg.models = vec![ModelSpec::K1, ModelSpec::WendlandSe];
    cfg.train.multistart.restarts = 2;
    cfg.workers = 1;
    cfg.exec = exec.clone();
    let mut rng = Xoshiro256::seed_from_u64(23);
    let result = Tournament::new(cfg).run(&data, &mut rng).expect("tournament");
    let dir = std::env::temp_dir();
    let path_good: PathBuf =
        dir.join(format!("gpfast_fault_good_{}.bin", std::process::id()));
    let path_bad: PathBuf = dir.join(format!("gpfast_fault_bad_{}.bin", std::process::id()));
    result.models[0].save(&path_good, &data).unwrap();
    result.models[1].save(&path_bad, &data).unwrap();
    // poison the second artifact: NaN into its α vector, framing intact
    let mut bytes = std::fs::read(&path_bad).unwrap();
    let off = find_f64(&bytes, result.models[1].train.peak_eval.alpha[2]);
    bytes[off..off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
    std::fs::write(&path_bad, &bytes).unwrap();

    // the poisoned file must fail hydration cleanly, alone or in a roster
    let err = match TrainedModel::load(&path_bad) {
        Err(e) => e,
        Ok(_) => panic!("NaN artifact hydrated"),
    };
    assert!(format!("{err:#}").contains("corrupt artifact"), "{err:#}");
    assert!(
        ServeSession::from_artifacts(&[&path_bad, &path_good], exec.clone()).is_err(),
        "a roster containing a poisoned artifact must not come up"
    );
    // the session restarts from the survivor and serves finite values
    let session =
        ServeSession::from_artifacts(&[&path_good], exec.clone()).expect("survivor restart");
    let p = session.predict(&[5.5, 11.25]);
    assert!(p.mean.iter().chain(&p.sd).all(|v| v.is_finite()));
    assert_eq!(session.n_quarantined(), 0);
    let _ = std::fs::remove_file(&path_good);
    let _ = std::fs::remove_file(&path_bad);
}
