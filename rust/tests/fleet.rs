//! Fleet acceptance suite: the multi-tenant LRU serving cache must be
//! **free** (zero likelihood evaluations on the hydration path),
//! **lossless** (dirty evictions round-trip observations through the
//! artifact store bit-identically), **deterministic** (the same request
//! stream produces the same predictions, eviction order and final store
//! bytes at any thread budget) and **honest about corruption** (a
//! flipped payload byte in a stored blob fails hydration with a clean
//! CRC error instead of serving garbage).
//!
//! Eval accounting uses [`CounterSnapshot`] — per-thread deltas, so this
//! suite runs under cargo's default concurrent test threads without the
//! process-global counter races the persistence suite used to serialise
//! behind a mutex.

use gpfast::coordinator::{
    ArtifactStore, Fleet, MemoryStore, ModelSpec, PredictRequest, ServeSession, TrainResult,
    TrainedModel, ZipfWorkload,
};
use gpfast::data::synthetic::table1_dataset;
use gpfast::data::Dataset;
use gpfast::evidence::LaplaceEvidence;
use gpfast::gp::{profiled, CounterSnapshot};
use gpfast::linalg::Matrix;
use gpfast::priors::BoxPrior;
use gpfast::runtime::ExecutionContext;

/// Deterministic artifact without the optimiser: one profiled eval at
/// the prior mid-point (the persistence-suite recipe).
fn make_artifact(spec: ModelSpec, data: &Dataset, ln_z: f64) -> TrainedModel {
    let sigma_n = 0.1;
    let model = spec.build(sigma_n);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut theta: Vec<f64> = prior.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
    prior.project(&mut theta);
    let ev = profiled::eval(&model, &data.t, &data.y, &theta).expect("mid-prior eval");
    let m = model.dim();
    TrainedModel {
        spec,
        sigma_n,
        param_names: model.kernel.names(),
        train: TrainResult {
            theta_hat: theta,
            lnp_peak: ev.lnp,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            peak_eval: ev,
            converged: true,
            n_evals: 0,
            n_modes: 1,
            restart_values: Vec::new(),
        },
        evidence: LaplaceEvidence {
            ln_z,
            ln_p_peak: ln_z,
            ln_det_h: 0.0,
            ln_volume: 0.0,
            marg_const: 0.0,
            sigma: vec![0.0; m],
            covariance: Matrix::zeros(m, m),
            suspect: false,
        },
        nested: None,
        warm_started: false,
        restarts: 0,
        wall_secs: 0.0,
    }
}

/// A two-model session roster (k1 ranked above k2) as store blobs.
fn session_blobs(data: &Dataset) -> Vec<Vec<u8>> {
    let a = make_artifact(ModelSpec::K1, data, -9.0);
    let b = make_artifact(ModelSpec::K2, data, -11.0);
    vec![a.to_bytes(data).expect("encode k1"), b.to_bytes(data).expect("encode k2")]
}

/// Hydrating a cold session from the store and serving its first
/// prediction costs **zero** profiled-likelihood evaluations — the whole
/// point of shipping factors inside the artifact.
#[test]
fn hydration_pays_zero_likelihood_evaluations() {
    let data = table1_dataset(24, 0.1, 907);
    let mut store = MemoryStore::new();
    store.put("tenant", session_blobs(&data)).unwrap();
    let mut fleet = Fleet::new(store, 1, ExecutionContext::seq());
    let t_star: Vec<f64> = (0..16).map(|q| 0.3 + 1.17 * q as f64).collect();

    let snap = CounterSnapshot::take();
    let pred = fleet.predict("tenant", &t_star).expect("cold predict");
    let delta = snap.delta();
    assert_eq!(
        delta.evals, 0,
        "hydration + first predict must not pay any likelihood evaluation"
    );
    assert!(pred.mean.iter().all(|m| m.is_finite()));
    let stats = fleet.stats();
    assert_eq!(stats.hydrations, 1);
    assert_eq!(stats.hits, 0);
    assert!(stats.hydrate_parse_secs >= 0.0 && stats.hydrate_adopt_secs >= 0.0);

    // second touch is a hit: still zero evals, no new hydration
    let snap = CounterSnapshot::take();
    let again = fleet.predict("tenant", &t_star).expect("hot predict");
    assert_eq!(snap.delta().evals, 0);
    assert_eq!(fleet.stats().hydrations, 1);
    assert_eq!(fleet.stats().hits, 1);
    assert_eq!(again.mean, pred.mean, "hot path must serve the same bits");
    assert_eq!(again.sd, pred.sd);
}

/// Capacity-1 thrash: two tenants alternating through a single slot.
/// Every cycle evicts and rehydrates both, and every cycle serves
/// bit-identical predictions — the LRU is invisible to the answers.
#[test]
fn evicted_then_rehydrated_sessions_serve_identical_bits() {
    let data = table1_dataset(24, 0.1, 911);
    let mut store = MemoryStore::new();
    store.put("a", session_blobs(&data)).unwrap();
    store.put("b", session_blobs(&data)).unwrap();
    let mut fleet = Fleet::new(store, 1, ExecutionContext::seq());
    let t_star: Vec<f64> = (0..12).map(|q| 0.5 + 1.9 * q as f64).collect();

    let first_a = fleet.predict("a", &t_star).unwrap();
    let first_b = fleet.predict("b", &t_star).unwrap();
    assert!(!fleet.is_resident("a"), "capacity 1: b must have evicted a");
    for cycle in 0..3 {
        let pa = fleet.predict("a", &t_star).unwrap();
        let pb = fleet.predict("b", &t_star).unwrap();
        assert_eq!(pa.mean, first_a.mean, "cycle {cycle}: a mean drifted");
        assert_eq!(pa.sd, first_a.sd, "cycle {cycle}: a sd drifted");
        assert_eq!(pb.mean, first_b.mean, "cycle {cycle}: b mean drifted");
        assert_eq!(pb.sd, first_b.sd, "cycle {cycle}: b sd drifted");
    }
    let stats = fleet.stats();
    assert_eq!(stats.hits, 0, "capacity-1 alternation can never hit");
    assert_eq!(stats.hydrations, 8);
    assert_eq!(stats.evictions, 7, "every hydration after the first evicts");
    assert_eq!(stats.persisted, 0, "clean sessions must not be written back");
    // eviction order is the strict alternation
    let want: Vec<String> =
        ["a", "b", "a", "b", "a", "b", "a"].iter().map(|s| s.to_string()).collect();
    assert_eq!(fleet.eviction_log(), &want[..]);
}

/// Observations streamed into a resident session survive eviction: the
/// dirty write-back re-serialises the live factors, and the rehydrated
/// session serves bit-identically to a control session that never left
/// memory.
#[test]
fn dirty_eviction_round_trips_observations() {
    let data = table1_dataset(24, 0.1, 917);
    let exec = ExecutionContext::seq();
    let tm_a = make_artifact(ModelSpec::K1, &data, -9.0);
    let tm_b = make_artifact(ModelSpec::K2, &data, -11.0);
    let mut control =
        ServeSession::from_tournament(&[tm_a, tm_b], &data, exec.clone()).unwrap();

    let mut store = MemoryStore::new();
    store.put("tenant", session_blobs(&data)).unwrap();
    store.put("bystander", session_blobs(&data)).unwrap();
    let bytes_before = store.get("tenant").unwrap().unwrap();
    let mut fleet = Fleet::new(store, 1, exec);

    let new_points = [(25.5, 0.31), (26.25, -0.42), (27.0, 0.11)];
    for &(t, y) in &new_points {
        fleet.observe("tenant", t, y).unwrap();
        control.observe(t, y).unwrap();
    }
    // cache pressure: hydrating the bystander evicts the dirty tenant
    let probe: Vec<f64> = (0..10).map(|q| 0.7 + 2.3 * q as f64).collect();
    let _ = fleet.predict("bystander", &probe).unwrap();
    assert!(!fleet.is_resident("tenant"));
    assert_eq!(fleet.stats().persisted, 1, "dirty eviction must write back");
    let bytes_after = fleet.store().get("tenant").unwrap().unwrap();
    assert_ne!(bytes_before, bytes_after, "write-back must capture the new observations");

    // rehydrate and compare against the in-memory control
    let got = fleet.predict("tenant", &probe).unwrap();
    let want = control.predict(&probe);
    assert_eq!(got.mean, want.mean, "rehydrated observations must serve identical bits");
    assert_eq!(got.sd, want.sd);

    // the rehydrated copy is clean until touched again: a second
    // eviction must not write the store
    let persisted = fleet.stats().persisted;
    let _ = fleet.predict("bystander", &probe).unwrap();
    assert_eq!(fleet.stats().persisted, persisted);
}

/// One seeded Zipf workload — batched predicts interleaved with
/// observations — replayed at thread budgets 1 and 4: predictions,
/// eviction order and the final persisted store must match exactly.
fn run_workload(threads: usize) -> (Vec<Vec<f64>>, Vec<String>, Vec<String>, Vec<Vec<Vec<u8>>>) {
    let data = table1_dataset(24, 0.1, 31);
    let ids: Vec<String> = (0..6).map(|i| format!("w{i}")).collect();
    let mut store = MemoryStore::new();
    for id in &ids {
        store.put(id, session_blobs(&data)).unwrap();
    }
    let mut fleet = Fleet::new(store, 2, ExecutionContext::new(threads));
    let mut zipf = ZipfWorkload::new(ids.len(), 1.0, 77);
    let mut preds: Vec<Vec<f64>> = Vec::new();
    for chunk in 0..5usize {
        let reqs: Vec<PredictRequest> = (0..8usize)
            .map(|j| {
                let q = 1 + j % 3;
                let t_star: Vec<f64> = (0..q)
                    .map(|k| 0.4 + 0.9 * (j + k) as f64 + 0.05 * chunk as f64)
                    .collect();
                PredictRequest { session_id: ids[zipf.next_session()].clone(), t_star }
            })
            .collect();
        for p in fleet.run_batch(&reqs).unwrap() {
            preds.push(p.mean);
            preds.push(p.sd);
        }
        // a deterministic dirtying observe per chunk
        fleet.observe(&reqs[0].session_id, 30.0 + chunk as f64, 0.2 * chunk as f64).unwrap();
    }
    fleet.evict_all().unwrap();
    let log = fleet.eviction_log().to_vec();
    let store = fleet.into_store();
    let final_ids = store.ids().unwrap();
    let blobs = final_ids.iter().map(|id| store.get(id).unwrap().unwrap()).collect();
    (preds, log, final_ids, blobs)
}

#[test]
fn fleet_workload_is_deterministic_across_thread_budgets() {
    let (p1, log1, ids1, blobs1) = run_workload(1);
    let (p4, log4, ids4, blobs4) = run_workload(4);
    assert_eq!(p1, p4, "predictions must be bit-identical at 1 vs 4 threads");
    assert_eq!(log1, log4, "eviction order must not depend on the thread budget");
    assert_eq!(ids1, ids4);
    assert_eq!(blobs1, blobs4, "persisted store bytes must be bit-identical");
    assert!(!log1.is_empty(), "the workload must actually exercise eviction");
}

/// `run_batch` answers land in request order with per-request shapes,
/// and batching a mixed-session stream matches the one-at-a-time path
/// bit for bit.
#[test]
fn run_batch_matches_sequential_predicts() {
    let data = table1_dataset(24, 0.1, 919);
    let ids = ["r0", "r1", "r2"];
    let mut store = MemoryStore::new();
    for id in ids {
        store.put(id, session_blobs(&data)).unwrap();
    }
    let mut fleet = Fleet::new(store, 2, ExecutionContext::new(2));
    let reqs: Vec<PredictRequest> = (0..9usize)
        .map(|j| PredictRequest {
            session_id: ids[j % 3].to_string(),
            t_star: (0..1 + j % 2).map(|k| 0.6 + 1.3 * (j + k) as f64).collect(),
        })
        .collect();
    let batched = fleet.run_batch(&reqs).unwrap();
    assert_eq!(batched.len(), reqs.len());

    let mut solo = Fleet::new(fleet.into_store(), 2, ExecutionContext::new(2));
    for (req, got) in reqs.iter().zip(&batched) {
        assert_eq!(got.mean.len(), req.t_star.len(), "per-request shape");
        let want = solo.predict(&req.session_id, &req.t_star).unwrap();
        assert_eq!(got.mean, want.mean, "batched vs sequential mean");
        assert_eq!(got.sd, want.sd, "batched vs sequential sd");
    }
}

/// Freshly trained sessions enter the fleet dirty via `admit` and are
/// persisted by `flush`; unknown tenants and corrupted store blobs
/// surface as clean errors.
#[test]
fn admit_flush_and_failure_modes() {
    let data = table1_dataset(24, 0.1, 923);
    let exec = ExecutionContext::seq();
    let tm_a = make_artifact(ModelSpec::K1, &data, -9.0);
    let tm_b = make_artifact(ModelSpec::K2, &data, -11.0);
    let session = ServeSession::from_tournament(&[tm_a, tm_b], &data, exec.clone()).unwrap();

    let mut fleet = Fleet::new(MemoryStore::new(), 2, exec);
    fleet.admit("live", session).unwrap();
    assert!(!fleet.store().contains("live"), "admit alone must not touch the store");
    assert_eq!(fleet.flush().unwrap(), 1, "flush writes the dirty admission");
    assert!(fleet.store().contains("live"));
    assert_eq!(fleet.flush().unwrap(), 0, "flush is idempotent on clean residents");

    // unknown tenant: clean error, no counters corrupted
    let err = fleet.predict("ghost", &[1.0]).expect_err("unknown id");
    assert!(format!("{err}").contains("unknown session"), "unexpected: {err}");

    // a flipped payload byte in a stored blob must fail hydration with
    // the CRC error, not serve corrupted factors
    let mut blobs = session_blobs(&data);
    let mid = blobs[0].len() / 2;
    blobs[0][mid] ^= 0x01;
    let mut store = MemoryStore::new();
    store.put("corrupt", blobs).unwrap();
    let mut fleet = Fleet::new(store, 1, ExecutionContext::seq());
    let err = fleet.predict("corrupt", &[1.0]).expect_err("corrupt blob");
    let msg = format!("{err}");
    assert!(msg.contains("corrupt artifact"), "want a CRC complaint, got: {msg}");
}

/// A two-model session roster encoded as v4 store blobs.
fn session_blobs_v4(data: &Dataset) -> Vec<Vec<u8>> {
    let a = make_artifact(ModelSpec::K1, data, -9.0);
    let b = make_artifact(ModelSpec::K2, data, -11.0);
    vec![
        a.to_bytes_v4(data, None).expect("encode k1 v4"),
        b.to_bytes_v4(data, None).expect("encode k2 v4"),
    ]
}

/// The v4 store path under capacity-1 thrash: a fleet reading v4 blobs
/// serves bit-identical answers to the v3 fleet, pays zero likelihood
/// evaluations, never touches the v2/v3 field-stream parser (hydrations
/// go through the zero-copy view), and dirty write-backs re-encode in
/// v4 and round-trip observations bit-identically.
#[test]
fn v4_store_thrash_serves_identical_bits_without_the_parser() {
    let data = table1_dataset(24, 0.1, 937);
    let mut store3 = MemoryStore::new();
    store3.put("a", session_blobs(&data)).unwrap();
    store3.put("b", session_blobs(&data)).unwrap();
    let mut fleet3 = Fleet::new(store3, 1, ExecutionContext::seq());

    let mut store4 = MemoryStore::new();
    store4.put("a", session_blobs_v4(&data)).unwrap();
    store4.put("b", session_blobs_v4(&data)).unwrap();
    let mut fleet4 = Fleet::new(store4, 1, ExecutionContext::seq());
    fleet4.set_artifact_format(4, None).unwrap();

    let t_star: Vec<f64> = (0..12).map(|q| 0.5 + 1.9 * q as f64).collect();
    let snap = CounterSnapshot::take();
    for cycle in 0..3 {
        let p3a = fleet3.predict("a", &t_star).unwrap();
        let p4a = fleet4.predict("a", &t_star).unwrap();
        assert_eq!(p4a.mean, p3a.mean, "cycle {cycle}: v4 means diverged from v3");
        assert_eq!(p4a.sd, p3a.sd, "cycle {cycle}: v4 sds diverged from v3");
        let p3b = fleet3.predict("b", &t_star).unwrap();
        let p4b = fleet4.predict("b", &t_star).unwrap();
        assert_eq!(p4b.mean, p3b.mean, "cycle {cycle}: v4 means diverged from v3 (b)");
        assert_eq!(p4b.sd, p3b.sd, "cycle {cycle}: v4 sds diverged from v3 (b)");
    }
    assert_eq!(snap.delta().evals, 0, "v4 hydration must stay eval-free");
    let st = fleet4.stats();
    assert_eq!(st.hydrations, 6, "capacity-1 alternation rehydrates every touch");
    assert_eq!(st.hydrate_parse_secs, 0.0, "v4 hydration must never touch the v2/v3 parser");
    assert!(st.hydrate_view_secs > 0.0, "v4 hydration must be timed through the view phase");
    assert!(st.hydrate_adopt_secs > 0.0, "factor adoption must be timed");
    let st3 = fleet3.stats();
    assert_eq!(st3.hydrate_view_secs, 0.0, "v3 hydration has no view phase");
    assert!(st3.hydrate_parse_secs > 0.0, "v3 hydration must be timed through the parser");
    assert_eq!(fleet4.eviction_log(), fleet3.eviction_log(), "eviction order must match");

    // dirty write-back stays v4: observe, evict under pressure, check
    // the stored version bytes, then rehydrate bit-identically against
    // a control session that never left memory
    let tm_a = make_artifact(ModelSpec::K1, &data, -9.0);
    let tm_b = make_artifact(ModelSpec::K2, &data, -11.0);
    let mut control =
        ServeSession::from_tournament(&[tm_a, tm_b], &data, ExecutionContext::seq()).unwrap();
    for &(t, y) in &[(25.5, 0.31), (26.25, -0.42)] {
        fleet4.observe("a", t, y).unwrap();
        control.observe(t, y).unwrap();
    }
    let _ = fleet4.predict("b", &t_star).unwrap(); // pressure: evicts dirty "a"
    assert!(!fleet4.is_resident("a"));
    assert_eq!(fleet4.stats().persisted, 1, "dirty v4 eviction must write back");
    for blob in fleet4.store().get("a").unwrap().unwrap() {
        assert_eq!(&blob[8..12], &4u32.to_le_bytes()[..], "write-back must stay format v4");
    }
    let probe: Vec<f64> = (0..10).map(|q| 0.7 + 2.6 * q as f64).collect();
    let got = fleet4.predict("a", &probe).unwrap();
    let want = control.predict(&probe);
    assert_eq!(got.mean, want.mean, "v4 write-back must round-trip observations bit-identically");
    assert_eq!(got.sd, want.sd);
}
