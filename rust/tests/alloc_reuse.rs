//! The `linalg::micro` scratch-arena contract: once a thread has warmed
//! the per-thread pack/mirror buffers, further GEMM/TRSM calls of the
//! same (or smaller) footprint perform **zero heap allocations** — the
//! ≈290 KB-per-call pack scratch of the pre-arena kernels is gone.
//!
//! Counted with a thread-local counting wrapper around the system
//! allocator, so the parallel test harness (and any other test threads)
//! cannot pollute the count. This file deliberately holds a single test.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use gpfast::linalg::micro::{self, Clip};
use gpfast::rng::Xoshiro256;

thread_local! {
    static ALLOC_CALLS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates everything to `System`; the bookkeeping is a
// thread-local counter bump (Cell<u64> has no destructor, so the TLS
// access cannot itself allocate or recurse).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOC_CALLS.with(|c| c.get())
}

fn randv(len: usize, rng: &mut Xoshiro256) -> Vec<f64> {
    (0..len).map(|_| rng.normal()).collect()
}

#[test]
fn warm_micro_kernels_do_not_allocate() {
    let mut rng = Xoshiro256::seed_from_u64(1);
    // k = 300 spans two KC chunks; m, n exceed one register tile
    let (m, n, k) = (96usize, 80, 300);
    let a = randv(m * k, &mut rng);
    let b = randv(k * n, &mut rng);
    let mut c = vec![0.0; m * n];

    // lower triangle for the TRSMs (well conditioned)
    let nn = 97usize;
    let q = 5usize;
    let mut l = vec![0.0; nn * nn];
    for i in 0..nn {
        for j in 0..i {
            l[i * nn + j] = 0.3 * rng.normal() / (nn as f64).sqrt();
        }
        l[i * nn + i] = 2.0 + 0.1 * rng.normal().abs();
    }
    let rhs = randv(q * nn, &mut rng);
    let mut x = rhs.clone();

    // --- warm-up: first calls may grow the thread-local arena
    micro::gemm_nn(&mut c, n, m, n, k, &a, k, &b, n, 1.0, Clip::None);
    micro::gemm_nt(&mut c, n, m, n, k, &a, k, &b, k, 1.0, Clip::None);
    micro::solve_lower_rows(&l, nn, nn, &mut x, nn, q);
    micro::solve_lower_transpose_rows(&l, nn, nn, &mut x, nn, q);

    // --- warm runs must not touch the heap at all
    let before = allocs_on_this_thread();
    micro::gemm_nn(&mut c, n, m, n, k, &a, k, &b, n, 1.0, Clip::None);
    assert_eq!(
        allocs_on_this_thread() - before,
        0,
        "warm gemm_nn allocated on the pack path"
    );

    let before = allocs_on_this_thread();
    micro::gemm_nt(&mut c, n, m, n, k, &a, k, &b, k, -1.0, Clip::Lower(0));
    assert_eq!(
        allocs_on_this_thread() - before,
        0,
        "warm gemm_nt allocated on the pack path"
    );

    x.copy_from_slice(&rhs);
    let before = allocs_on_this_thread();
    micro::solve_lower_rows(&l, nn, nn, &mut x, nn, q);
    assert_eq!(
        allocs_on_this_thread() - before,
        0,
        "warm solve_lower_rows allocated (mirror or pack path)"
    );

    let before = allocs_on_this_thread();
    micro::solve_lower_transpose_rows(&l, nn, nn, &mut x, nn, q);
    assert_eq!(
        allocs_on_this_thread() - before,
        0,
        "warm solve_lower_transpose_rows allocated (mirror or pack path)"
    );

    // sanity: the warm TRSM still solves the system (L·Lᵀ x = rhs)
    for r in 0..q {
        // recompute L (Lᵀ x) and compare against rhs
        let xr = &x[r * nn..(r + 1) * nn];
        let mut lt_x = vec![0.0; nn];
        for i in 0..nn {
            for j in i..nn {
                lt_x[i] += l[j * nn + i] * xr[j];
            }
        }
        for i in 0..nn {
            let mut got = 0.0;
            for j in 0..=i {
                got += l[i * nn + j] * lt_x[j];
            }
            let want = rhs[r * nn + i];
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "row {r} comp {i}: {got} vs {want}"
            );
        }
    }
}
