//! Golden-value regression tests: fixed deterministic configurations with
//! hard-coded expected values, so refactors of the linalg / assembly /
//! likelihood stack cannot silently drift the numerics.
//!
//! The constants were computed **independently of this crate** in
//! 60-digit mpmath arithmetic by `python/tools/golden_values.py` (same
//! kernel and likelihood definitions, re-derived from the paper; see the
//! script header). On these small well-conditioned cases the rust f64
//! pipeline matches the infinite-precision value to ~1e-12, so the 1e-8
//! tolerance below has four orders of magnitude of headroom over rounding
//! while still catching any real numerical change.
//!
//! All cases fix ξ = 0, where `erfinv(0) = 0` exactly in both
//! implementations — no inverse-error-function approximation error enters
//! the comparison.

use gpfast::evidence::laplace_evidence;
use gpfast::gp::{marg_constant, profiled, profiled_hessian};
use gpfast::kernels::{paper_k1, paper_k2, DataSpan};
use gpfast::priors::{BoxPrior, ScalePrior};

fn assert_close(tag: &str, got: f64, want: f64) {
    let rel = (got - want).abs() / want.abs().max(1e-12);
    assert!(rel < 1e-8, "{tag}: got {got:.15e}, want {want:.15e} (rel {rel:.3e})");
}

/// Case 1 — compact support shorter than the grid spacing, so K̃ is
/// exactly diagonal `(1 + σ_n²) I` and every quantity has a closed form.
/// Exercises the profiled-likelihood bookkeeping in isolation.
#[test]
fn diagonal_limit_profiled_likelihood() {
    let t: Vec<f64> = (0..20).map(|i| (10 * i) as f64).collect();
    let y: Vec<f64> = t.iter().map(|&ti| (0.6 * ti).sin()).collect();
    // T0 = 5 < spacing 10 → all off-diagonal Wendland factors are 0
    let theta = vec![5f64.ln(), 1.0, 0.0];
    let model = paper_k1(0.1);
    let ev = profiled::eval(&model, &t, &y, &theta).unwrap();
    assert_close("lnp", ev.lnp, -22.071097804830362968);
    assert_close("sigma_f_hat2", ev.sigma_f_hat2, 0.52691416589029547117);
    assert_close("logdet", ev.chol.logdet(), 0.19900661706336165696);
}

/// Case 2 — dense k₁ Gram on the paper's unit grid (n = 24): the full
/// assembly → Cholesky → profiled-likelihood chain.
#[test]
fn dense_k1_profiled_likelihood() {
    let t: Vec<f64> = (1..=24).map(|i| i as f64).collect();
    let y: Vec<f64> =
        t.iter().map(|&ti| (0.6 * ti).sin() + 0.3 * (1.7 * ti).cos()).collect();
    let theta = vec![2.5, 1.5, 0.0];
    let model = paper_k1(0.1);
    let ev = profiled::eval(&model, &t, &y, &theta).unwrap();
    assert_close("lnp", ev.lnp, -9.8008114360305094054);
    assert_close("sigma_f_hat2", ev.sigma_f_hat2, 0.50519476384150638679);
    assert_close("logdet", ev.chol.logdet(), -32.119956647712934539);
}

/// Case 2 continued — the Laplace evidence (eq. 2.13) on the same
/// configuration: analytic Hessian (eq. 2.19), marginalisation constant
/// (eq. 2.18), prior volume and determinant, all pinned. The reference
/// Hessian was obtained by 60-digit central finite differences of the
/// mpmath likelihood, so this cross-validates the analytic eq.-2.19
/// machinery end to end.
#[test]
fn dense_k1_laplace_evidence() {
    let t: Vec<f64> = (1..=24).map(|i| i as f64).collect();
    let y: Vec<f64> =
        t.iter().map(|&ti| (0.6 * ti).sin() + 0.3 * (1.7 * ti).cos()).collect();
    let theta = vec![2.5, 1.5, 0.0];
    let model = paper_k1(0.1);
    let ev = profiled::eval(&model, &t, &y, &theta).unwrap();
    let hess = profiled_hessian(&model, &t, &y, &theta).unwrap();
    let prior = BoxPrior::for_model(&model, &DataSpan::from_times(&t).unwrap());
    let lap = laplace_evidence(24, &prior, &ScalePrior::default(), &theta, ev.lnp, &hess)
        .unwrap();
    assert_close("ln_det_h", lap.ln_det_h, 596502.92496166734402f64.ln());
    assert_close("marg_const", lap.marg_const, -3.6355110466180739935);
    assert_close("ln_volume", lap.ln_volume, 2.2855716125875437953);
    assert_close("ln_z", lap.ln_z, -19.614498207646199807);
}

/// Case 3 — dense k₂ (m = 5, two periodic factors) at the paper's truth
/// hyperparameters.
#[test]
fn dense_k2_profiled_likelihood() {
    let t: Vec<f64> = (1..=18).map(|i| i as f64).collect();
    let y: Vec<f64> =
        t.iter().map(|&ti| (0.6 * ti).sin() + 0.3 * (1.7 * ti).cos()).collect();
    let theta = vec![3.5, 1.5, 0.0, 2.5, 0.0];
    let model = paper_k2(0.1);
    let ev = profiled::eval(&model, &t, &y, &theta).unwrap();
    assert_close("lnp", ev.lnp, -10.816105861025334225);
    assert_close("sigma_f_hat2", ev.sigma_f_hat2, 1.018431706904351404);
    assert_close("logdet", ev.chol.logdet(), -29.778325705977773903);
}

/// Case 4 — the symmetric eigensolver (Householder tridiagonalisation +
/// implicit-shift QL, `sym_eigenvalues_with`) against 60-digit mpmath
/// `eigsy` eigenvalues of the fixed n = 64 k₁ Gram matrix
/// `K̃ = K + σ_n² I`. Pins the extreme and median eigenvalues, the trace
/// and the log-determinant (which must also agree with the Cholesky
/// logdet of the same matrix), sequentially and under a parallel
/// execution context.
#[test]
fn k1_gram_eigenvalues_n64() {
    use gpfast::gp::assemble_cov;
    use gpfast::linalg::{sym_eigenvalues, sym_eigenvalues_with, Chol, ExecutionContext};

    let t: Vec<f64> = (1..=64).map(|i| i as f64).collect();
    let theta = vec![2.5, 1.5, 0.0];
    let model = paper_k1(0.1);
    let k = assemble_cov(&model, &t, &theta);

    let golden = |evs: &[f64], tag: &str| {
        assert_eq!(evs.len(), 64);
        assert!(evs.windows(2).all(|w| w[0] <= w[1]), "{tag}: not ascending");
        assert_close(&format!("{tag} lam_min"), evs[0], 0.024785648781424137622);
        assert_close(&format!("{tag} lam_1"), evs[1], 0.024804086777898506112);
        assert_close(&format!("{tag} lam_mid"), evs[31], 0.33476811034680823505);
        assert_close(&format!("{tag} lam_sub"), evs[62], 6.1272276378457051914);
        assert_close(&format!("{tag} lam_max"), evs[63], 6.2909307421533728938);
        assert_close(&format!("{tag} trace"), evs.iter().sum::<f64>(), 64.64);
        assert_close(
            &format!("{tag} logdet"),
            evs.iter().map(|&e| e.ln()).sum::<f64>(),
            -88.968193055636497033,
        );
    };
    let seq = sym_eigenvalues(&k).unwrap();
    golden(&seq, "seq");
    let par = sym_eigenvalues_with(&k, &ExecutionContext::new(4)).unwrap();
    golden(&par, "par");
    // the tridiagonal-QL arithmetic is partition-independent: parallel
    // and sequential runs agree bit for bit
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.to_bits(), b.to_bits(), "seq/par eigenvalues diverge");
    }
    // independent cross-check: Σ ln λ must equal the Cholesky logdet
    let chol = Chol::factor(&k).unwrap();
    assert_close("chol logdet", chol.logdet(), -88.968193055636497033);
}

/// Case 5 — the Levinson–Durbin Toeplitz solver against the 60-digit
/// dense solve on the same fixed n = 64 k₁ Gram matrix as case 4 (which
/// is Toeplitz by construction on the uniform grid t = 1..64). Pins
/// selected components of `K̃⁻¹y`, the quadratic form `yᵀK̃⁻¹y`, and the
/// log-determinant — which must also reproduce the case-4
/// eigenvalue/Cholesky value, closing the loop between all three
/// factorisation paths.
#[test]
fn toeplitz_levinson_solve_n64() {
    use gpfast::gp::assemble_cov;
    use gpfast::linalg::{dot, ToeplitzSolver};

    let t: Vec<f64> = (1..=64).map(|i| i as f64).collect();
    let y: Vec<f64> =
        t.iter().map(|&ti| (0.6 * ti).sin() + 0.3 * (1.7 * ti).cos()).collect();
    let theta = vec![2.5, 1.5, 0.0];
    let model = paper_k1(0.1);
    let k = assemble_cov(&model, &t, &theta);
    // first row of the (Toeplitz) Gram is the lag sequence, σ_n² included
    let r: Vec<f64> = (0..64).map(|j| k[(0, j)]).collect();
    let ts = ToeplitzSolver::new(&r).unwrap();
    let x = ts.solve(&y);
    assert_close("x[0]", x[0], 0.0072500229417323533459);
    assert_close("x[1]", x[1], -0.64648008587845827511);
    assert_close("x[31]", x[31], -0.28400247180701097282);
    assert_close("x[63]", x[63], 0.53070489684839911209);
    assert_close("ytKinvy", dot(&y, &x), 32.052631861242875937);
    assert_close("logdet", ts.logdet(), -88.968193055636497033);
}

/// The marginalisation constant (eq. 2.18) alone, over a range of n —
/// pins `lgamma` and the constant's composition.
#[test]
fn marg_constant_golden() {
    // mpmath: marg_constant(n, 1e-3, 1e3) at n = 10, 100, 1968
    // -ln ln 1e6 - ln 2 + (n/2)(ln 2 + 1 - ln n) + lgamma(n/2)
    for (n, want) in [
        (10usize, -3.1880748268585123634f64),
        (100, -4.3543454200983730321),
        (1968, -5.8457288220134421047),
    ] {
        let got = marg_constant(n, 1e-3, 1e3);
        assert_close(&format!("marg({n})"), got, want);
    }
}

/// Case 6 — heteroscedastic SE-ARD (d = 3, n = 16): the scenario tier's
/// n×d assembly with a per-point noise diagonal, pinned against the
/// 60-digit mpmath reference. The input columns are integer-derived
/// (exact in f64) and the noise cycles through four σ levels, so no
/// Toeplitz or scalar fast path can reach this configuration — it pins
/// the general `eval_nd_with` chain itself.
#[test]
fn heteroscedastic_se_ard_profiled_likelihood() {
    use gpfast::kernels::{ArdKernel, CovarianceModel};
    use gpfast::runtime::ExecutionContext;

    let n = 16usize;
    let x1: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let x2: Vec<f64> = (1..=n).map(|i| ((7 * i) % 16) as f64 / 2.0).collect();
    let x3: Vec<f64> = (1..=n).map(|i| ((3 * i) % 5) as f64 / 4.0).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (0.6 * x1[i]).sin() + 0.3 * (1.7 * x2[i]).cos() - 0.2 * x3[i])
        .collect();
    let sig: Vec<f64> = (1..=n).map(|i| 0.05 * (1 + (i % 4)) as f64).collect();
    let theta = vec![0.5, 0.0, -0.3];
    let model = CovarianceModel::new("se-ard3", Box::new(ArdKernel::se(3)), 0.1);
    let x: Vec<&[f64]> = vec![&x1, &x2, &x3];
    let ev = profiled::eval_nd_with(&model, &x, Some(&sig), &y, &theta, &ExecutionContext::seq())
        .unwrap();
    assert_close("lnp", ev.lnp, -13.259958578396906566);
    assert_close("sigma_f_hat2", ev.sigma_f_hat2, 0.31754401301002881805);
    assert_close("logdet", ev.chol.logdet(), -0.53189436010567536641);
}
