//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so the subset of `anyhow`
//! this crate actually uses is vendored here: an opaque [`Error`] that any
//! `std::error::Error` converts into, the [`Result`] alias, and the
//! `anyhow!` / `bail!` / `ensure!` macros (format-string forms). No
//! context chains, no backtraces, no downcasting — none are used.

use std::error::Error as StdError;
use std::fmt;

/// An opaque boxed error.
///
/// Deliberately does **not** implement `std::error::Error` itself, so the
/// blanket `From<E: std::error::Error>` below cannot overlap with the
/// reflexive `From<Error> for Error` that `?` needs (the same trick the
/// real `anyhow` uses).
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap any displayable message into an error.
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { inner: Box::new(e) }
    }
}

struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError>
        ensure!(v >= 0, "negative: {v}");
        if v > 100 {
            bail!("too big: {v}");
        }
        Ok(v)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert!(parse("-3").unwrap_err().to_string().contains("negative"));
        assert!(parse("101").unwrap_err().to_string().contains("too big"));
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
        assert_eq!(format!("{e:?}"), "code 42");
    }

    #[test]
    fn ensure_without_message() {
        fn f(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(f(true).is_ok());
        assert!(f(false).unwrap_err().to_string().contains("condition failed"));
    }
}
